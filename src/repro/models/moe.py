"""Mixture-of-Experts layer: top-k routing with sort-based capacity dispatch.

Baseline formulation ("gspmd" path): global einsums/scatters with experts
sharded over 'model' and dispatch capacity over 'data'; GSPMD inserts the
collectives. The explicit expert-parallel all_to_all path (shard_map) is the
§Perf hillclimb target and lives in repro/dist/expert_parallel.py.

Router probe sites make this the flagship bpftime use case: per-expert load
and overflow-drop counters via eBPF maps (examples/moe_balance.py).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import events as E
from repro.dist.sharding import constrain

F32 = jnp.float32


def init_moe(key, cfg: ModelConfig):
    D, Fh, Ex = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(D)
    return {
        "router": jax.random.normal(k1, (D, Ex), F32) * s,
        "w_in": jax.random.normal(k2, (Ex, D, Fh), F32) * s,
        "w_gate": jax.random.normal(k3, (Ex, D, Fh), F32) * s,
        "w_out": jax.random.normal(k4, (Ex, Fh, D), F32) / math.sqrt(Fh),
    }


def capacity(cfg: ModelConfig, tokens: int) -> int:
    c = int(cfg.capacity_factor * tokens * cfg.experts_per_token
            / cfg.num_experts)
    return max(8, -(-c // 8) * 8)   # pad to 8 for layout friendliness


def route(p, x, cfg: ModelConfig):
    """Top-k routing + sort-based capacity dispatch, shared by the GSPMD
    path below and the explicit expert-parallel path
    (repro.dist.expert_parallel). x: [B, S, D] -> (disp [E, C, D], info)."""
    B, S, D = x.shape
    T = B * S
    k = cfg.experts_per_token
    Ex = cfg.num_experts
    dt = x.dtype
    xt = x.reshape(T, D)

    logits = (xt @ p["router"].astype(dt)).astype(F32)      # [T, E]
    logits = E.probe_site("moe.router", logits)
    gates = jax.nn.softmax(logits, axis=-1)
    gvals, gids = jax.lax.top_k(gates, k)                   # [T, k]
    gvals = gvals / jnp.maximum(gvals.sum(-1, keepdims=True), 1e-9)

    TK = T * k
    flat_ids = gids.reshape(TK)
    sort_idx = jnp.argsort(flat_ids)                        # stable
    sorted_eids = flat_ids[sort_idx]                        # [TK]
    # position within each expert's run of the sorted array
    first_idx = jnp.searchsorted(sorted_eids, sorted_eids, side="left")
    pos = jnp.arange(TK, dtype=jnp.int32) - first_idx.astype(jnp.int32)
    C = capacity(cfg, T)
    keep = pos < C
    pos_c = jnp.where(keep, pos, C)                         # slot C = trash
    tok_idx = (sort_idx // k).astype(jnp.int32)

    # dispatch: [E, C+1, D] — experts over 'model' (EP), capacity over 'data'
    disp = jnp.zeros((Ex, C + 1, D), dt)
    disp = disp.at[sorted_eids, pos_c].set(xt[tok_idx].astype(dt))
    info = dict(sorted_eids=sorted_eids, pos_c=pos_c, tok_idx=tok_idx,
                sort_idx=sort_idx, gvals=gvals, gids=gids, keep=keep, T=T)
    return disp[:, :C, :], info


def combine(out_e, info):
    """Scatter expert outputs back to tokens, weighted by gate values."""
    Ex, _, D = out_e.shape
    dt = out_e.dtype
    out_e = jnp.concatenate(
        [out_e, jnp.zeros((Ex, 1, D), dt)], axis=1)         # trash row
    contrib = out_e[info["sorted_eids"], info["pos_c"]]     # [TK, D]
    TK = info["sorted_eids"].shape[0]
    w = (info["gvals"].reshape(TK)[info["sort_idx"]]
         * info["keep"]).astype(dt)
    return jnp.zeros((info["T"], D), dt).at[info["tok_idx"]].add(
        contrib * w[:, None])


def router_probes(info, cfg: ModelConfig):
    """Router health stats for probe sites: per-expert load + drops."""
    load = jnp.sum(jax.nn.one_hot(info["gids"].reshape(-1), cfg.num_experts,
                                  dtype=F32), axis=0)
    E.probe_site("moe.load", load)
    drops = jnp.sum((~info["keep"]).astype(F32))
    E.probe_site("moe.drops", drops.reshape(1))


def apply_moe(p, x, cfg: ModelConfig):
    """x: [B, S, D] -> [B, S, D]. Sort-based dropping dispatch."""
    B, S, D = x.shape
    dt = x.dtype
    disp, info = route(p, x, cfg)
    disp = constrain(disp, "model", "data", None)

    # expert FFN (swiglu)
    h = jnp.einsum("ecd,edf->ecf", disp, p["w_in"].astype(dt))
    h = constrain(h, "model", "data", None)
    g = jnp.einsum("ecd,edf->ecf", disp, p["w_gate"].astype(dt))
    g = constrain(g, "model", "data", None)
    h = jax.nn.silu(g.astype(F32)).astype(dt) * h
    out_e = jnp.einsum("ecf,efd->ecd", h, p["w_out"].astype(dt))
    out_e = constrain(out_e, "model", "data", None)

    out = combine(out_e, info)
    router_probes(info, cfg)
    return out.reshape(B, S, D)


def aux_load_balance_loss(p, x, cfg: ModelConfig):
    """Switch-style load-balance auxiliary loss (optional, used in train)."""
    B, S, D = x.shape
    xt = x.reshape(B * S, D)
    logits = (xt @ p["router"].astype(x.dtype)).astype(F32)
    gates = jax.nn.softmax(logits, axis=-1)
    ids = jnp.argmax(gates, axis=-1)
    me = jnp.mean(gates, axis=0)
    ce = jnp.mean(jax.nn.one_hot(ids, cfg.num_experts, dtype=F32), axis=0)
    return cfg.num_experts * jnp.sum(me * ce)
