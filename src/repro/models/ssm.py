"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) block.

Training/prefill uses the chunked dual form: intra-chunk attention-like
matmuls + inter-chunk state recurrence (a lax.scan over chunk states) — all
MXU-friendly contractions, the TPU-native shape of the SSD algorithm.
Decode uses the O(1) recurrent step on a carried (conv, ssm) state cache.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.dist.sharding import constrain

F32 = jnp.float32


def init_mamba(key, cfg: ModelConfig):
    D = cfg.d_model
    di = cfg.d_inner()
    G, N = cfg.ssm_ngroups, cfg.ssm_state
    nh = cfg.ssm_heads()
    K = cfg.ssm_conv
    proj_out = 2 * di + 2 * G * N + nh    # z, x, B, C, dt
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(D)
    return {
        "in_proj": jax.random.normal(k1, (D, proj_out), F32) * s,
        "conv_w": jax.random.normal(k2, (K, di + 2 * G * N), F32) * 0.1,
        "conv_b": jnp.zeros((di + 2 * G * N,), F32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=F32)),
        "D": jnp.ones((nh,), F32),
        "dt_bias": jnp.full((nh,), math.log(math.e - 1), F32),
        "out_proj": jax.random.normal(k4, (di, D), F32) / math.sqrt(di),
        "norm_scale": jnp.ones((di,), F32),
    }


def _split_proj(zxbcdt, cfg):
    di = cfg.d_inner()
    G, N = cfg.ssm_ngroups, cfg.ssm_state
    z = zxbcdt[..., :di]
    xb = zxbcdt[..., di:2 * di]
    Bv = zxbcdt[..., 2 * di:2 * di + G * N]
    Cv = zxbcdt[..., 2 * di + G * N:2 * di + 2 * G * N]
    dt = zxbcdt[..., 2 * di + 2 * G * N:]
    return z, xb, Bv, Cv, dt


def _causal_conv(x, w, b, state=None):
    """depthwise causal conv. x: [B, S, C]; w: [K, C]. state: [B, K-1, C]
    (decode). Returns (y, new_state)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                   # [B, S+K-1, C]
    y = sum(xp[:, i:i + x.shape[1], :] * w[i].astype(x.dtype)
            for i in range(K))
    y = y + b.astype(x.dtype)
    new_state = xp[:, -(K - 1):, :]
    return jax.nn.silu(y.astype(F32)).astype(x.dtype), new_state


def _segsum(log_a):
    """log_a: [..., L] -> cumulative decay matrix [..., L, L]:
    out[i, j] = sum(log_a[j+1..i]) for j < i, -inf above diagonal."""
    L = log_a.shape[-1]
    cs = jnp.cumsum(log_a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]               # sum (j, i]
    ii = jnp.arange(L)
    mask = ii[:, None] >= ii[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(xh, dt, A, Bv, Cv, cfg: ModelConfig):
    """SSD dual form.
    xh: [B, S, H, P]; dt: [B, S, H] (post-softplus); A: [H] (negative);
    Bv, Cv: [B, S, G, N]. Returns y [B, S, H, P]."""
    Bsz, S, H, P = xh.shape
    G, N = cfg.ssm_ngroups, cfg.ssm_state
    L = min(cfg.ssm_chunk, S)
    assert S % L == 0
    nc = S // L
    rep = H // G

    xc = xh.reshape(Bsz, nc, L, H, P)
    dtc = dt.reshape(Bsz, nc, L, H)
    Bc = Bv.reshape(Bsz, nc, L, G, N)
    Cc = Cv.reshape(Bsz, nc, L, G, N)
    dA = dtc * A                                             # [B, nc, L, H]
    dA_cs = jnp.cumsum(dA, axis=2)                           # within chunk

    # ---- intra-chunk (the "attention" quadrant)
    Lmat = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))        # [B,nc,H,L,L]
    # scores: C_i . B_j  -> [B, nc, H, L, L]
    CB = jnp.einsum("bclgn,bcsgn->bcgls", Cc.astype(F32), Bc.astype(F32))
    CB = jnp.repeat(CB, rep, axis=2)                          # G -> H
    scores = CB * Lmat * dtc.transpose(0, 1, 3, 2)[:, :, :, None, :]
    y_diag = jnp.einsum("bchls,bcshp->bclhp", scores, xc.astype(F32))

    # ---- chunk states: h_c = sum_s exp(dA_cs[L-1] - dA_cs[s]) dt_s B_s x_s
    decay_to_end = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)       # [B,nc,L,H]
    w = (dtc * decay_to_end).astype(F32)                      # [B,nc,L,H]
    Brep = jnp.repeat(Bc, rep, axis=3)                        # [B,nc,L,H,N]
    states = jnp.einsum("bclh,bclhn,bclhp->bchpn",
                        w, Brep.astype(F32), xc.astype(F32))

    # ---- inter-chunk recurrence over nc (sequential scan)
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])                 # [B,nc,H]

    def step(h, inp):
        st, dec = inp                                         # [B,H,P,N],[B,H]
        h_new = h * dec[..., None, None] + st
        return h_new, h                                       # emit PREVIOUS

    h0 = jnp.zeros((Bsz, H, P, N), F32)
    h_final, h_prev = lax.scan(step, h0,
                               (states.transpose(1, 0, 2, 3, 4),
                                chunk_decay.transpose(1, 0, 2)))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)                  # [B,nc,H,P,N]

    # ---- inter-chunk output: y_off = C_l . (decay_in * h_prev)
    decay_in = jnp.exp(dA_cs)                                 # [B,nc,L,H]
    Crep = jnp.repeat(Cc, rep, axis=3)                        # [B,nc,L,H,N]
    y_off = jnp.einsum("bclhn,bchpn,bclh->bclhp",
                       Crep.astype(F32), h_prev, decay_in)
    y = (y_diag + y_off).reshape(Bsz, S, H, P)
    return y.astype(xh.dtype), h_final


def apply_mamba(p, x, cfg: ModelConfig, *, cache=None, return_state=False):
    """x: [B, S, D]. cache: None (train/prefill) or dict(conv, ssm) for
    decode (S must be 1). return_state=True (prefill) returns the final
    (conv, ssm) state as the new cache. Returns (y [B,S,D], new_cache)."""
    Bsz, S, D = x.shape
    dt_ = x.dtype
    di = cfg.d_inner()
    G, N = cfg.ssm_ngroups, cfg.ssm_state
    H, P = cfg.ssm_heads(), cfg.ssm_headdim

    zxbcdt = x @ p["in_proj"].astype(dt_)
    zxbcdt = constrain(zxbcdt, "batch", None, None)
    z, xb, Bv, Cv, dtr = _split_proj(zxbcdt, cfg)
    conv_in = jnp.concatenate([xb, Bv, Cv], axis=-1)

    A = -jnp.exp(p["A_log"])                                  # [H], negative
    if cache is None:
        conv_out, conv_state = _causal_conv(conv_in, p["conv_w"],
                                            p["conv_b"])
        xb = conv_out[..., :di]
        Bv = conv_out[..., di:di + G * N].reshape(Bsz, S, G, N)
        Cv = conv_out[..., di + G * N:].reshape(Bsz, S, G, N)
        dt = jax.nn.softplus(dtr.astype(F32) + p["dt_bias"])  # [B,S,H]
        xh = xb.reshape(Bsz, S, H, P)
        y, h_final = ssd_chunked(xh, dt, A, Bv, Cv, cfg)
        y = y + xh * p["D"].astype(dt_)[None, None, :, None]   # skip path
        new_cache = ({"conv": conv_state.astype(dt_), "ssm": h_final}
                     if return_state else None)
    else:
        conv_out, conv_state = _causal_conv(conv_in, p["conv_w"],
                                            p["conv_b"], cache["conv"])
        xb = conv_out[..., :di]
        Bv = conv_out[..., di:di + G * N].reshape(Bsz, S, G, N)
        Cv = conv_out[..., di + G * N:].reshape(Bsz, S, G, N)
        dt = jax.nn.softplus(dtr.astype(F32) + p["dt_bias"])  # [B,1,H]
        xh = xb.reshape(Bsz, S, H, P)
        # recurrent step (S == 1)
        dA = jnp.exp(dt[:, 0] * A)                            # [B,H]
        Brep = jnp.repeat(Bv[:, 0], H // G, axis=1)           # [B,H,N]
        Crep = jnp.repeat(Cv[:, 0], H // G, axis=1)
        h = cache["ssm"]                                      # [B,H,P,N] f32
        upd = (dt[:, 0, :, None, None] * xh[:, 0].astype(F32)[..., None]
               * Brep.astype(F32)[:, :, None, :])
        h = h * dA[..., None, None] + upd
        y1 = jnp.einsum("bhpn,bhn->bhp", h, Crep.astype(F32))
        y = (y1[:, None].astype(dt_)
             + xh * p["D"].astype(dt_)[None, None, :, None])
        new_cache = {"conv": conv_state.astype(dt_), "ssm": h}

    # gated RMSNorm (mamba2's norm-before-out_proj)
    yf = y.reshape(Bsz, S, di).astype(F32)
    yf = yf * jax.nn.silu(z.astype(F32))
    ms = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    yf = yf * lax.rsqrt(ms + 1e-5) * p["norm_scale"]
    out = yf.astype(dt_) @ p["out_proj"].astype(dt_)
    return out, new_cache


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype):
    di = cfg.d_inner()
    G, N = cfg.ssm_ngroups, cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di + 2 * G * N), dtype),
        "ssm": jnp.zeros((batch, cfg.ssm_heads(), cfg.ssm_headdim, N), F32),
    }
