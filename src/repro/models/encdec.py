"""Encoder-decoder backbone (SeamlessM4T-medium's T2TT/S2TT transformer).

The audio/text modality frontend is a STUB per the assignment: encoder
inputs arrive as precomputed frame embeddings [B, S_enc, D]. Encoder is
non-causal self-attention; decoder is causal self-attention + cross
attention. Both stacks are homogeneous lax.scans (probed).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.core import events as E
from repro.core.events import probe_site
from . import layers as L

F32 = jnp.float32


def _init_enc_layer(key, cfg):
    return {
        "norm1": L.init_norm(key, cfg),
        "attn": L.init_attention(jax.random.fold_in(key, 1), cfg),
        "norm2": L.init_norm(jax.random.fold_in(key, 2), cfg),
        "mlp": L.init_mlp(jax.random.fold_in(key, 3), cfg),
    }


def _init_dec_layer(key, cfg):
    p = _init_enc_layer(key, cfg)
    p["norm_x"] = L.init_norm(jax.random.fold_in(key, 4), cfg)
    p["xattn"] = L.init_attention(jax.random.fold_in(key, 5), cfg)
    return p


def init_params(key, cfg: ModelConfig) -> dict:
    ke, kd, kemb, kf1, kf2 = jax.random.split(key, 5)
    enc = jax.vmap(lambda k: _init_enc_layer(k, cfg))(
        jax.random.split(ke, cfg.enc_layers))
    dec = jax.vmap(lambda k: _init_dec_layer(k, cfg))(
        jax.random.split(kd, cfg.dec_layers))
    return {
        "embed": L.init_embedding(kemb, cfg),
        "encoder": enc,
        "decoder": dec,
        "enc_norm": L.init_norm(kf1, cfg),
        "dec_norm": L.init_norm(kf2, cfg),
    }


def encode(params, embeds, cfg: ModelConfig, remat: bool = False):
    """embeds: [B, S_enc, D] (frontend stub output)."""
    B, S, _ = embeds.shape
    x = embeds.astype(L.cdtype(cfg))
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = probe_site("enc.in", x)

    def body(c, p):
        h = L.apply_norm(p["norm1"], c, cfg)
        q, k, v = L._qkv(p["attn"], h, cfg)
        q = L.apply_rope(q, pos, cfg)
        k = L.apply_rope(k, pos, cfg)
        if S > 2048:
            o = L.flash_attention(q, k, v, causal=False,
                                  q_chunk=min(2048, S),
                                  kv_chunk=min(2048, S))
        else:
            o = L.full_attention(q, k, v, causal=False)
        c = c + (o.reshape(B, S, -1) @ p["attn"]["wo"].astype(c.dtype))
        h2 = L.apply_norm(p["norm2"], c, cfg)
        c = c + L.apply_mlp(p["mlp"], h2, cfg)
        c = probe_site("enc.block", c, kind=E.KIND_EXIT)
        return c, None

    x, _ = E.probed_scan(body, x, params["encoder"], remat=remat)
    return L.apply_norm(params["enc_norm"], x, cfg)


def _cross_kv(p_layer, enc_out, cfg):
    B, Se, _ = enc_out.shape
    KH, hd = cfg.num_kv_heads, cfg.hd
    k = (enc_out @ p_layer["xattn"]["wk"].astype(enc_out.dtype))
    v = (enc_out @ p_layer["xattn"]["wv"].astype(enc_out.dtype))
    return k.reshape(B, Se, KH, hd), v.reshape(B, Se, KH, hd)


def decode_train(params, tokens, enc_out, cfg: ModelConfig,
                 remat: bool = False):
    """Teacher-forced decoder pass. tokens: [B, S_dec]."""
    x = L.embed(params["embed"], tokens, cfg)
    B, S, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(c, p):
        h = L.apply_norm(p["norm1"], c, cfg)
        out, _ = L.attention_block(p["attn"], h, pos, cfg)
        c = c + out
        hx = L.apply_norm(p["norm_x"], c, cfg)
        xkv = _cross_kv(p, enc_out, cfg)
        xout, _ = L.attention_block(p["xattn"], hx, pos, cfg, cross_kv=xkv)
        c = c + xout
        h2 = L.apply_norm(p["norm2"], c, cfg)
        c = c + L.apply_mlp(p["mlp"], h2, cfg)
        c = probe_site("dec.block", c, kind=E.KIND_EXIT)
        return c, None

    x, _ = E.probed_scan(body, x, params["decoder"], remat=remat)
    x = L.apply_norm(params["dec_norm"], x, cfg)
    return L.unembed(params["embed"], x, cfg).astype(F32)


def forward_train(params, batch, cfg: ModelConfig, remat: bool = False):
    enc_out = encode(params, batch["enc_embeds"], cfg, remat=remat)
    return decode_train(params, batch["tokens"], enc_out, cfg, remat=remat)


# ------------------------------------------------------------------ serving

def init_dec_cache(cfg: ModelConfig, batch: int, max_seq: int, enc_seq: int,
                   dtype) -> dict:
    n = cfg.dec_layers
    KH, hd = cfg.num_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((n, batch, max_seq, KH, hd), dtype),
        "v": jnp.zeros((n, batch, max_seq, KH, hd), dtype),
        "xk": jnp.zeros((n, batch, enc_seq, KH, hd), dtype),
        "xv": jnp.zeros((n, batch, enc_seq, KH, hd), dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def prefill(params, tokens, enc_out, cache, cfg: ModelConfig):
    """Teacher-forced prefill of S_dec tokens + cross-kv precompute."""
    x = L.embed(params["embed"], tokens, cfg)
    B, S, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(c, xs):
        p, ck = xs
        h = L.apply_norm(p["norm1"], c, cfg)
        out, kv = L.attention_block(p["attn"], h, pos, cfg)
        k_new = lax.dynamic_update_slice_in_dim(
            ck["k"], kv[0].astype(ck["k"].dtype), 0, axis=1)
        v_new = lax.dynamic_update_slice_in_dim(
            ck["v"], kv[1].astype(ck["v"].dtype), 0, axis=1)
        c = c + out
        hx = L.apply_norm(p["norm_x"], c, cfg)
        xk, xv = _cross_kv(p, enc_out, cfg)
        xout, _ = L.attention_block(p["xattn"], hx, pos, cfg,
                                    cross_kv=(xk, xv))
        c = c + xout
        h2 = L.apply_norm(p["norm2"], c, cfg)
        c = c + L.apply_mlp(p["mlp"], h2, cfg)
        nc = {"k": k_new, "v": v_new,
              "xk": xk.astype(ck["xk"].dtype), "xv": xv.astype(ck["xv"].dtype)}
        return c, nc

    xs = (params["decoder"], {"k": cache["k"], "v": cache["v"],
                              "xk": cache["xk"], "xv": cache["xv"]})
    x, nc = E.probed_scan(body, x, xs)
    x = L.apply_norm(params["dec_norm"], x, cfg)
    logits = L.unembed(params["embed"], x, cfg).astype(F32)
    return logits, {**nc, "pos": cache["pos"] + S}


def decode_step(params, tokens, cache, cfg: ModelConfig):
    """tokens: [B, 1]. Returns (logits [B,1,V], new cache)."""
    x = L.embed(params["embed"], tokens, cfg)
    B = x.shape[0]
    pos = cache["pos"][:, None]

    def body(c, xs):
        p, ck = xs
        h = L.apply_norm(p["norm1"], c, cfg)
        out, kv = L.attention_block(p["attn"], h, pos, cfg,
                                    cache=(ck["k"], ck["v"]),
                                    cache_pos=cache["pos"])
        c = c + out
        hx = L.apply_norm(p["norm_x"], c, cfg)
        xout, _ = L.attention_block(p["xattn"], hx, pos, cfg,
                                    cross_kv=(ck["xk"], ck["xv"]))
        c = c + xout
        h2 = L.apply_norm(p["norm2"], c, cfg)
        c = c + L.apply_mlp(p["mlp"], h2, cfg)
        nc = {"k": kv[0], "v": kv[1], "xk": ck["xk"], "xv": ck["xv"]}
        return c, nc

    xs = (params["decoder"], {"k": cache["k"], "v": cache["v"],
                              "xk": cache["xk"], "xv": cache["xv"]})
    x, nc = E.probed_scan(body, x, xs)
    x = L.apply_norm(params["dec_norm"], x, cfg)
    logits = L.unembed(params["embed"], x, cfg).astype(F32)
    return logits, {**nc, "pos": cache["pos"] + 1}
