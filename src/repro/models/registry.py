"""Uniform model entry points per family: init / loss / prefill / decode."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from . import encdec as ED, layers as L, transformer as TF

F32 = jnp.float32


def init_params(key, cfg: ModelConfig):
    if cfg.family == "encdec":
        return ED.init_params(key, cfg)
    return TF.init_params(key, cfg)


def cross_entropy(logits, labels, vocab_size=None):
    """logits f32 [B,S,Vpad]; labels i32 [B,S], -1 = masked. Padding logits
    (>= vocab_size) are excluded from the partition function.

    Sharding-aware: label log-prob extraction uses an iota mask + reduce
    instead of take_along_axis — a vocab-dim gather would force an
    all-gather of the FULL logits tensor on TP meshes (40GB/step for a
    4k x 256 batch at 152k vocab; found via the dry-run HLO audit, see
    EXPERIMENTS.md §Perf iteration 2)."""
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                    logits.ndim - 1)
    if vocab_size is not None and vocab_size < logits.shape[-1]:
        logits = jnp.where(iota >= vocab_size, jnp.float32(-1e30), logits)
    mask = (labels >= 0).astype(F32)
    lab = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.sum(jnp.where(iota == lab[..., None], logits, 0.0), axis=-1)
    nll = (logz - ll) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)


def loss_fn(params, batch, cfg: ModelConfig, *, remat=False):
    """batch: tokens/labels (+ enc_embeds | embeds/positions)."""
    if cfg.family == "encdec":
        logits = ED.forward_train(params, batch, cfg, remat=remat)
        labels = batch["labels"]
    else:
        logits, _ = TF.forward(params, batch["tokens"], cfg,
                               embeds=batch.get("embeds"),
                               positions=batch.get("positions"),
                               mode="train", remat=remat)
        labels = batch["labels"]
    loss = cross_entropy(logits, labels, cfg.vocab_size)
    return loss, {"loss": loss}


def make_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype,
               enc_seq: int = 0):
    if cfg.family == "encdec":
        return ED.init_dec_cache(cfg, batch, max_seq, enc_seq or max_seq,
                                 dtype)
    return TF.init_cache(cfg, batch, max_seq, dtype)


def prefill_fn(params, batch, cache, cfg: ModelConfig):
    if cfg.family == "encdec":
        enc_out = ED.encode(params, batch["enc_embeds"], cfg)
        return ED.prefill(params, batch["tokens"], enc_out, cache, cfg)
    logits, cache = TF.forward(params, batch["tokens"], cfg,
                               embeds=batch.get("embeds"),
                               positions=batch.get("positions"),
                               cache=cache, mode="prefill")
    return logits, cache


def decode_fn(params, tokens, cache, cfg: ModelConfig):
    """tokens [B,1] -> (logits [B,1,V], cache)."""
    if cfg.family == "encdec":
        return ED.decode_step(params, tokens, cache, cfg)
    return TF.forward(params, tokens, cfg, cache=cache, mode="decode")
