"""Core model layers: norms, RoPE/M-RoPE, GQA attention (chunked
flash-style for long sequences, grouped-head einsums — KV is never
materialized repeated), SwiGLU/GeLU MLP, embeddings.

Probe sites (`E.probe_site`) are the uprobe attach points — zero-cost when
nothing is attached (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.core import events as E
from repro.dist.sharding import constrain

F32 = jnp.float32


def cdtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def init_norm(key, cfg: ModelConfig, d=None):
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), F32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), F32)
    return p


def apply_norm(p, x, cfg: ModelConfig):
    xf = x.astype(F32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"] + p["bias"]
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * lax.rsqrt(ms + cfg.norm_eps) * p["scale"]
    return y.astype(x.dtype)


# --------------------------------------------------------------------------
# rotary embeddings (RoPE + M-RoPE)
# --------------------------------------------------------------------------

def _rope_freqs(cfg: ModelConfig):
    hd = cfg.hd
    exponent = jnp.arange(0, hd, 2, dtype=F32) / hd
    return 1.0 / (cfg.rope_theta ** exponent)          # [hd/2]


def apply_rope(x, positions, cfg: ModelConfig):
    """x: [..., S, H, hd]; positions: [..., S] (i32) or [..., S, 3] for
    M-RoPE (temporal/height/width sections, Qwen2-VL)."""
    hd = cfg.hd
    freqs = _rope_freqs(cfg)                            # [hd/2]
    if cfg.rope_kind == "mrope":
        assert positions.ndim == x.ndim - 1, "mrope needs [..., S, 3] ids"
        sec = cfg.mrope_sections
        idx = jnp.concatenate([
            jnp.full((sec[0],), 0, jnp.int32),
            jnp.full((sec[1],), 1, jnp.int32),
            jnp.full((sec[2],), 2, jnp.int32)])         # [hd/2]
        pos = jnp.take_along_axis(
            positions.astype(F32),
            jnp.broadcast_to(idx, positions.shape[:-1] + (hd // 2,)).astype(jnp.int32),
            axis=-1)                                    # [..., S, hd/2]
        ang = pos * freqs
    else:
        ang = positions.astype(F32)[..., None] * freqs  # [..., S, hd/2]
    cos = jnp.cos(ang)[..., None, :]                    # [..., S, 1, hd/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention (GQA, grouped-head; flash-chunked for long sequences)
# --------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig):
    D, H, KH, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(D)
    p = {
        "wq": (jax.random.normal(k1, (D, H * hd), F32) * s),
        "wk": (jax.random.normal(k2, (D, KH * hd), F32) * s),
        "wv": (jax.random.normal(k3, (D, KH * hd), F32) * s),
        "wo": (jax.random.normal(k4, (H * hd, D), F32) * s),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), F32)
        p["bk"] = jnp.zeros((KH * hd,), F32)
        p["bv"] = jnp.zeros((KH * hd,), F32)
    return p


def _qkv(p, x, cfg: ModelConfig):
    B, S, D = x.shape
    H, KH, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    dt = x.dtype
    q = x @ p["wq"].astype(dt)
    k = x @ p["wk"].astype(dt)
    v = x @ p["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    # Head-parallel attention when H divides the model axis; otherwise fall
    # back to SEQUENCE-parallel q/k/v (k/v stay small under GQA and get
    # all-gathered cheaply) — §Perf iteration 4 (opt-in via
    # REPRO_SEQ_PAR_ATTN=1; baseline keeps the replicated-head fallback):
    # without this, GQA models with H % model != 0 all-gather full
    # activations every layer.
    import os
    from repro.dist.sharding import active_mesh
    mesh = active_mesh()
    seq_par_enabled = os.environ.get("REPRO_SEQ_PAR_ATTN", "0") == "1"
    head_par = (mesh is None or H % mesh.shape.get("model", 1) == 0
                or not seq_par_enabled)
    if head_par:
        q = constrain(q.reshape(B, S, H, hd), "batch", None, "model", None)
        k = constrain(k.reshape(B, S, KH, hd), "batch", None, "model", None)
        v = constrain(v.reshape(B, S, KH, hd), "batch", None, "model", None)
    else:
        q = constrain(q.reshape(B, S, H, hd), "batch", "model", None, None)
        k = constrain(k.reshape(B, S, KH, hd), "batch", "model", None, None)
        v = constrain(v.reshape(B, S, KH, hd), "batch", "model", None, None)
    return q, k, v


def _grouped(q, KH):
    """[B, S, H, hd] -> [B, S, KH, R, hd]"""
    B, S, H, hd = q.shape
    return q.reshape(B, S, KH, H // KH, hd)


def full_attention(q, k, v, *, causal, q_offset=0, kv_len=None):
    """Small-S / decode path. q: [B,Sq,H,hd]; k,v: [B,Skv,KH,hd].
    kv_len: [B] valid cache length mask (decode)."""
    B, Sq, H, hd = q.shape
    KH = k.shape[2]
    qg = _grouped(q, KH)
    s = jnp.einsum("bqkrh,bskh->bkrqs", qg.astype(F32), k.astype(F32))
    s = s / math.sqrt(hd)
    Skv = k.shape[1]
    if causal:
        qpos = q_offset + jnp.arange(Sq)[:, None]
        kpos = jnp.arange(Skv)[None, :]
        s = jnp.where(qpos >= kpos, s, -jnp.inf)
    if kv_len is not None:
        mask = jnp.arange(Skv)[None, :] < kv_len[:, None]       # [B, Skv]
        s = jnp.where(mask[:, None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkrqs,bskh->bqkrh", p, v.astype(F32))
    return o.reshape(B, Sq, H, hd).astype(q.dtype)


def flash_attention(q, k, v, *, causal=True, q_chunk=2048, kv_chunk=2048):
    """Chunked online-softmax attention (the JAX flash formulation):
    outer scan over q chunks, inner scan over kv chunks, f32 accumulators.
    Never materializes [Sq, Skv]. The whole body is tagged 'flash_interior'
    (jax.named_scope): on the TPU target the Pallas kernel
    (kernels/flash_attention.py) executes this computation with the interior
    resident in VMEM, so the dry-run analyzer buckets these HBM bytes
    separately (see hlo_cost.HloCost.bytes_flash_interior)."""
    with jax.named_scope("flash_interior"):
        return _flash_attention_impl(q, k, v, causal=causal,
                                     q_chunk=q_chunk, kv_chunk=kv_chunk)


def _flash_attention_impl(q, k, v, *, causal, q_chunk, kv_chunk):
    B, Sq, H, hd = q.shape
    KH = k.shape[2]
    Skv = k.shape[1]
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    assert Sq % q_chunk == 0 and Skv % kv_chunk == 0
    nq, nk = Sq // q_chunk, Skv // kv_chunk
    scale = 1.0 / math.sqrt(hd)

    qg = _grouped(q, KH).reshape(B, nq, q_chunk, KH, H // KH, hd)
    kc = k.reshape(B, nk, kv_chunk, KH, hd)
    vc = v.reshape(B, nk, kv_chunk, KH, hd)
    qpos_c = jnp.arange(q_chunk)
    kpos_c = jnp.arange(kv_chunk)

    def q_step(_, qi_i):
        qi, i = qi_i                      # [B, Lq, KH, R, hd], scalar
        R = qi.shape[3]
        m0 = jnp.full((B, KH, R, q_chunk), -jnp.inf, F32)
        l0 = jnp.zeros((B, KH, R, q_chunk), F32)
        a0 = jnp.zeros((B, q_chunk, KH, R, hd), F32)

        def kv_step(carry, kv_j):
            m, l, acc = carry
            kj, vj, j = kv_j
            s = jnp.einsum("bqkrh,bskh->bkrqs", qi.astype(F32),
                           kj.astype(F32)) * scale
            if causal:
                qp = i * q_chunk + qpos_c[:, None]
                kp = j * kv_chunk + kpos_c[None, :]
                s = jnp.where(qp >= kp, s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            # guard fully-masked rows (exp(-inf - -inf))
            m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(jnp.isneginf(s), 0.0, p)
            corr = jnp.exp(jnp.where(jnp.isneginf(m), m_new, m) - m_safe)
            corr = jnp.where(jnp.isneginf(m), 0.0, corr)
            l = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkrqs,bskh->bqkrh", p, vj.astype(F32))
            acc = acc * corr.transpose(0, 3, 1, 2)[..., None] + pv
            return (m_new, l, acc), None

        (m, l, acc), _ = lax.scan(
            kv_step, (m0, l0, a0),
            (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4),
             jnp.arange(nk)))
        l = jnp.maximum(l, 1e-20)
        out = acc / l.transpose(0, 3, 1, 2)[..., None]
        return None, out.astype(q.dtype)

    _, outs = lax.scan(q_step, None,
                       (qg.transpose(1, 0, 2, 3, 4, 5), jnp.arange(nq)))
    # outs: [nq, B, Lq, KH, R, hd]
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H, hd)
    return out


FLASH_THRESHOLD = 8192


def attention_block(p, x, positions, cfg: ModelConfig, *, cache=None,
                    cache_pos=None, cross_kv=None):
    """Full attention sublayer. Modes:
      train/prefill: cache=None (prefill returns fresh kv for caching)
      decode: cache=(k,v) [B,Smax,KH,hd], cache_pos [B] current length
      cross:  cross_kv=(k,v) precomputed from encoder (no rope)
    Returns (out, new_cache_kv)."""
    B, S, D = x.shape
    if cross_kv is not None:
        H, hd = cfg.num_heads, cfg.hd
        q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, H, hd)
        k, v = cross_kv
        o = full_attention(q, k, v, causal=False)
        out = (o.reshape(B, S, H * hd) @ p["wo"].astype(x.dtype))
        return out, None

    q, k, v = _qkv(p, x, cfg)
    if cfg.rope_kind != "none":
        q = apply_rope(q, positions, cfg)
        k = apply_rope(k, positions, cfg)

    if cache is not None:
        ck, cv = cache
        # decode: write k/v at each row's cache_pos
        ck = jax.vmap(lambda c, u, i: lax.dynamic_update_slice_in_dim(
            c, u, i, axis=0))(ck, k.astype(ck.dtype), cache_pos)
        cv = jax.vmap(lambda c, u, i: lax.dynamic_update_slice_in_dim(
            c, u, i, axis=0))(cv, v.astype(cv.dtype), cache_pos)
        o = full_attention(q, ck, cv, causal=False,
                           kv_len=cache_pos + S)
        out = (o.reshape(B, S, cfg.num_heads * cfg.hd)
               @ p["wo"].astype(x.dtype))
        return out, (ck, cv)

    if S > FLASH_THRESHOLD:
        o = flash_attention(q, k, v, causal=True)
    else:
        o = full_attention(q, k, v, causal=True) if S <= 2048 else \
            flash_attention(q, k, v, causal=True,
                            q_chunk=min(2048, S), kv_chunk=min(2048, S))
    out = o.reshape(B, S, cfg.num_heads * cfg.hd) @ p["wo"].astype(x.dtype)
    return out, (k, v)


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff=None):
    D, Fh = cfg.d_model, (d_ff or cfg.d_ff)
    k1, k2, k3 = jax.random.split(key, 3)
    s = 1.0 / math.sqrt(D)
    p = {"wi": jax.random.normal(k1, (D, Fh), F32) * s,
         "wo": jax.random.normal(k3, (Fh, D), F32) / math.sqrt(Fh)}
    if cfg.act == "swiglu":
        p["wg"] = jax.random.normal(k2, (D, Fh), F32) * s
    return p


def apply_mlp(p, x, cfg: ModelConfig):
    dt = x.dtype
    h = x @ p["wi"].astype(dt)
    h = constrain(h, "batch", None, "model")
    if cfg.act == "swiglu":
        g = x @ p["wg"].astype(dt)
        g = constrain(g, "batch", None, "model")
        h = jax.nn.silu(g.astype(F32)).astype(dt) * h
    else:
        h = jax.nn.gelu(h.astype(F32)).astype(dt)
    return h @ p["wo"].astype(dt)


# --------------------------------------------------------------------------
# embeddings / head
# --------------------------------------------------------------------------

def init_embedding(key, cfg: ModelConfig):
    p = {"embedding": jax.random.normal(
        key, (cfg.padded_vocab, cfg.d_model), F32) * 0.02}
    if not cfg.tie_embeddings:
        p["lm_head"] = jax.random.normal(
            jax.random.fold_in(key, 1), (cfg.d_model, cfg.padded_vocab),
            F32) * 0.02
    return p


def embed(p, tokens, cfg: ModelConfig):
    return p["embedding"].astype(cdtype(cfg))[tokens]


def unembed(p, x, cfg: ModelConfig):
    w = (p["embedding"].T if cfg.tie_embeddings else p["lm_head"])
    return x @ w.astype(x.dtype)
