"""Generic decoder-only stacked-block model.

One implementation covers dense / MoE / SSM (mamba2) / hybrid (jamba) / VLM
via the config's per-layer pattern: layer i = mixer(attn|mamba) + ffn
(dense|moe|none). Layers are grouped into SUPERBLOCKS (cfg.superblock
consecutive layers — the repeating heterogeneous unit); parameters are
stacked across superblocks and the stack is a lax.scan (probed_scan: probe
events flow out as stacked ys; remat wraps the superblock).

Probe sites: block (uprobe/uretprobe), attn.out, ffn.out, moe.router,
moe.load, moe.drops, embed.out, logits.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.core import events as E
from repro.core.events import probe_site
from repro.dist.sharding import constrain
from . import layers as L, moe as MOE, ssm as SSM

F32 = jnp.float32


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _init_superblock(key, cfg: ModelConfig):
    blocks = []
    for j in range(cfg.superblock):
        kj = jax.random.fold_in(key, j)
        kind, ffn = cfg.block_kind(j), cfg.ffn_kind(j)
        p = {"norm1": L.init_norm(kj, cfg)}
        if kind == "attn":
            p["attn"] = L.init_attention(jax.random.fold_in(kj, 1), cfg)
        else:
            p["mamba"] = SSM.init_mamba(jax.random.fold_in(kj, 2), cfg)
        if ffn != "none":
            p["norm2"] = L.init_norm(jax.random.fold_in(kj, 3), cfg)
            if ffn == "moe":
                p["moe"] = MOE.init_moe(jax.random.fold_in(kj, 4), cfg)
                if cfg.moe_shared:
                    p["mlp_shared"] = L.init_mlp(
                        jax.random.fold_in(kj, 6), cfg, d_ff=cfg.moe_d_ff)
            else:
                p["mlp"] = L.init_mlp(jax.random.fold_in(kj, 5), cfg)
        blocks.append(p)
    return {"blocks": blocks}


def init_params(key, cfg: ModelConfig) -> dict:
    assert cfg.num_layers % cfg.superblock == 0, \
        f"{cfg.name}: num_layers % superblock != 0"
    n_super = cfg.num_layers // cfg.superblock
    k_emb, k_stack, k_fin = jax.random.split(key, 3)
    keys = jax.random.split(k_stack, n_super)
    stack = jax.vmap(lambda k: _init_superblock(k, cfg))(keys)
    return {
        "embed": L.init_embedding(k_emb, cfg),
        "stack": stack,
        "final_norm": L.init_norm(k_fin, cfg),
    }


# --------------------------------------------------------------------------
# cache
# --------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype) -> dict:
    n_super = cfg.num_layers // cfg.superblock
    blocks = []
    for j in range(cfg.superblock):
        if cfg.block_kind(j) == "attn":
            kv_shape = (n_super, batch, max_seq, cfg.num_kv_heads, cfg.hd)
            blocks.append({"k": jnp.zeros(kv_shape, dtype),
                           "v": jnp.zeros(kv_shape, dtype)})
        else:
            c = SSM.init_mamba_cache(cfg, batch, dtype)
            blocks.append(jax.tree.map(
                lambda a: jnp.broadcast_to(a, (n_super,) + a.shape), c))
    return {"blocks": blocks, "pos": jnp.zeros((batch,), jnp.int32)}


def _moe_dispatch(p, x, cfg: ModelConfig):
    """GSPMD sort-based MoE by default; explicit shard_map expert
    parallelism with REPRO_MOE_EP=1 (requires an active mesh whose 'model'
    axis divides num_experts) — §Perf hillclimb 1."""
    import os
    from repro.dist.sharding import active_mesh
    mesh = active_mesh()
    if (os.environ.get("REPRO_MOE_EP", "0") == "1" and mesh is not None
            and "model" in mesh.axis_names
            and cfg.num_experts % mesh.shape["model"] == 0):
        from repro.dist.expert_parallel import apply_moe_ep
        return apply_moe_ep(p, x, cfg)
    return MOE.apply_moe(p, x, cfg)


# --------------------------------------------------------------------------
# superblock forward
# --------------------------------------------------------------------------

def _superblock_fwd(p_sb, x, cache_sb, positions, cfg: ModelConfig,
                    mode: str, cache_pos):
    import os
    sp_residual = os.environ.get("REPRO_SP_RESIDUAL", "0") == "1"
    new_cache = []
    for j in range(cfg.superblock):
        kind, ffn = cfg.block_kind(j), cfg.ffn_kind(j)
        p = p_sb["blocks"][j]
        # Megatron-SP (opt-in): residual stream sequence-sharded over
        # 'model' between blocks — norms run on 1/TP of the tokens and the
        # TP boundary becomes reduce-scatter/all-gather (§Perf iteration 5).
        if sp_residual and mode == "train":
            x = constrain(x, "batch", "model", None)
        else:
            x = constrain(x, "batch", None, None)
        x = probe_site("block", x, kind=E.KIND_ENTRY)
        h = L.apply_norm(p["norm1"], x, cfg)
        if kind == "attn":
            c = cache_sb["blocks"][j] if cache_sb is not None else None
            if mode == "train":
                out, _ = L.attention_block(p["attn"], h, positions, cfg)
                new_cache.append(None)
            elif mode == "prefill":
                out, kv = L.attention_block(p["attn"], h, positions, cfg)
                k_new, v_new = kv
                ck = lax.dynamic_update_slice_in_dim(
                    c["k"], k_new.astype(c["k"].dtype), 0, axis=1)
                cv = lax.dynamic_update_slice_in_dim(
                    c["v"], v_new.astype(c["v"].dtype), 0, axis=1)
                new_cache.append({"k": ck, "v": cv})
            else:  # decode
                out, kv = L.attention_block(p["attn"], h, positions, cfg,
                                            cache=(c["k"], c["v"]),
                                            cache_pos=cache_pos)
                new_cache.append({"k": kv[0], "v": kv[1]})
        else:
            c = cache_sb["blocks"][j] if cache_sb is not None else None
            if mode == "train":
                out, _ = SSM.apply_mamba(p["mamba"], h, cfg)
                new_cache.append(None)
            elif mode == "prefill":
                out, mc = SSM.apply_mamba(p["mamba"], h, cfg, cache=None,
                                          return_state=True)
                new_cache.append(mc)
            else:
                out, mc = SSM.apply_mamba(p["mamba"], h, cfg, cache=c)
                new_cache.append(mc)
        out = probe_site("attn.out" if kind == "attn" else "ssm.out", out)
        x = x + out

        if ffn != "none":
            h2 = L.apply_norm(p["norm2"], x, cfg)
            if ffn == "moe":
                f = _moe_dispatch(p["moe"], h2, cfg)
                if cfg.moe_shared:
                    f = f + L.apply_mlp(p["mlp_shared"], h2, cfg)
            else:
                f = L.apply_mlp(p["mlp"], h2, cfg)
            f = probe_site("ffn.out", f)
            x = x + f
        x = probe_site("block", x, kind=E.KIND_EXIT)
    return x, new_cache


# --------------------------------------------------------------------------
# full forward
# --------------------------------------------------------------------------

def forward(params, tokens, cfg: ModelConfig, *, embeds=None, positions=None,
            cache=None, mode: str = "train", remat: bool = False):
    """tokens: [B, S_text] i32; embeds: [B, S_front, D] modality stub
    (prepended); positions: [B, S] (or [B, S, 3] for mrope; default iota).
    Returns (logits f32 [B, S, V], new_cache | None)."""
    x = L.embed(params["embed"], tokens, cfg)
    if embeds is not None:
        x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    if positions is None:
        if mode == "decode" and cache is not None:
            positions = cache["pos"][:, None]                  # [B, 1]
        else:
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32),
                                         (B, S))
        if cfg.rope_kind == "mrope":
            positions = jnp.repeat(positions[..., None], 3, axis=-1)
    x = constrain(x, "batch", None, None)
    x = probe_site("embed.out", x)

    cache_pos = cache["pos"] if (cache is not None and mode == "decode") \
        else None
    cache_blocks = cache if cache is not None else None

    def body(carry, xs):
        x = carry
        p_sb, c_sb = xs
        x, nc = _superblock_fwd(p_sb, x, c_sb, positions, cfg, mode,
                                cache_pos)
        return x, nc

    n_super = cfg.num_layers // cfg.superblock
    if cache_blocks is not None:
        xs = (params["stack"], {"blocks": cache_blocks["blocks"]})
    else:
        xs = (params["stack"], None)

    if xs[1] is None:
        def body2(c, p_sb):
            y, _ = body(c, (p_sb, None))
            return y, None
        x, _ = E.probed_scan(body2, x, params["stack"], remat=remat)
        new_cache = None
    else:
        def body3(c, xs_):
            return body(c, xs_)
        x, new_blocks = E.probed_scan(body3, x, xs, remat=remat)
        new_pos = cache["pos"] + (S if mode != "train" else 0)
        new_cache = {"blocks": new_blocks, "pos": new_pos}

    x = L.apply_norm(params["final_norm"], x, cfg)
    logits = L.unembed(params["embed"], x, cfg).astype(F32)
    logits = constrain(logits, "batch", None, "model")
    logits = probe_site("logits", logits)
    return logits, new_cache
