"""The jitted train step: loss -> grads (with microbatch accumulation and
remat) -> clip -> optimizer -> probe-execution stage.

bpftime integration points:
  * model probe sites fire during the forward (uprobe analogue);
  * step-level sites: 'loss', 'grad.norm', 'optimizer.update';
  * the probe stage runs ONCE per step over the whole event tape, fully
    in-graph (the paper's no-context-switch property);
  * a 'filter'-style program that calls override_return on any device event
    makes the step SKIP the optimizer update (guard-rail semantics —
    syscall-filter behavior applied to training, e.g. NaN-loss batches).

State pytree:  {params, opt, step, maps, aux_rand}
Batch layout:  [microbatches, micro_bs, seq] when accumulating, else [B, S].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.core import events as E, jit as J
from repro.models import registry as MR
from repro.optim import (clip_by_global_norm, make_optimizer, warmup_cosine)

F32 = jnp.float32


def init_train_state(key, cfg: ModelConfig, tcfg: TrainConfig, runtime=None):
    params = MR.init_params(key, cfg)
    if tcfg.param_dtype == "bfloat16":
        params = jax.tree.map(lambda p: p.astype(jnp.bfloat16), params)
    opt_init, _ = make_optimizer(tcfg.optimizer)
    maps = runtime.init_device_maps() if runtime is not None else {}
    return {
        "params": params,
        "opt": opt_init(params),
        "step": jnp.zeros((), jnp.int32),
        "maps": maps,
    }


def abstract_train_state(cfg: ModelConfig, tcfg: TrainConfig, runtime=None):
    """ShapeDtypeStruct tree without allocating (for the dry-run)."""
    return jax.eval_shape(
        lambda: init_train_state(jax.random.PRNGKey(0), cfg, tcfg, runtime))


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, runtime=None,
                    probe_mode: str | None = None):
    _, opt_update = make_optimizer(tcfg.optimizer)
    collector_wanted = runtime.wanted_sites() if runtime else set()

    def train_step(state, batch):
        params = state["params"]
        col = E.Collector(collector_wanted) if runtime else None

        def loss_and_events(p, mb):
            def compute():
                loss, metrics = MR.loss_fn(p, mb, cfg, remat=tcfg.remat)
                if col is not None:
                    E.probe_site("loss", loss.reshape(1))
                return loss, metrics
            if col is None:
                loss, metrics = compute()
                rows = jnp.zeros((0, E.EVENT_WIDTH), jnp.int64)
                return loss, (metrics, rows)
            with col.frame() as fr:
                loss, metrics = compute()
                rows = col.stacked_rows(fr)
            return loss, (metrics, rows)

        grad_fn = jax.value_and_grad(loss_and_events, has_aux=True)

        ctx = col if col is not None else _nullcontext()
        with ctx:
            if tcfg.microbatch and batch["tokens"].ndim == 3:
                def micro(carry, mb):
                    acc = carry
                    (loss, (metrics, rows)), grads = grad_fn(params, mb)
                    acc = jax.tree.map(
                        lambda a, g: a + g.astype(F32), acc, grads)
                    return acc, (loss, rows)

                zero = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, F32), params)
                acc, (losses, rows_stack) = jax.lax.scan(micro, zero, batch)
                nmb = batch["tokens"].shape[0]
                grads = jax.tree.map(lambda a: a / nmb, acc)
                loss = losses.mean()
                rows = rows_stack.reshape(-1, E.EVENT_WIDTH)
            else:
                (loss, (metrics, rows)), grads = grad_fn(params, batch)

            grads, gnorm = clip_by_global_norm(grads, tcfg.clip_norm)
            if tcfg.grad_compression == "int8":
                from repro.dist.compression import int8_roundtrip
                grads = int8_roundtrip(grads)

            if col is not None:
                with col.frame() as fr:
                    E.probe_site("grad.norm", gnorm.reshape(1))
                    E.probe_site("optimizer.update", loss.reshape(1))
                    rows2 = col.stacked_rows(fr)
                rows = jnp.concatenate([rows, rows2], axis=0)

        lr = warmup_cosine(state["step"], lr=tcfg.lr, warmup=tcfg.warmup,
                           total=tcfg.total_steps)
        new_params, new_opt = opt_update(
            params, grads, state["opt"], lr,
            weight_decay=tcfg.weight_decay, step=state["step"])

        # ---- probe execution stage (in-graph; the bpftime hot path)
        maps = state["maps"]
        aux = J.make_aux(time_ns=state["step"].astype(jnp.int64))
        if runtime is not None and rows.shape[0] > 0:
            rows = rows.at[:, 3].set(state["step"].astype(jnp.int64))
            maps, aux = runtime.probe_stage(rows, maps, aux,
                                            mode=probe_mode)
            # filter semantics: an override vetoes this step's update
            veto = aux["override_set"] != 0
            new_params = jax.tree.map(
                lambda n, o: jnp.where(veto, o, n), new_params, params)
            new_opt = jax.tree.map(
                lambda n, o: jnp.where(veto, o, n), new_opt, state["opt"])

        new_state = {
            "params": new_params,
            "opt": new_opt,
            "step": state["step"] + 1,
            "maps": maps,
        }
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr,
                   "vetoed": aux["override_set"] if runtime is not None
                   else jnp.zeros((), jnp.int64)}
        return new_state, metrics

    return train_step


class _nullcontext:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False
