"""Deterministic synthetic data pipeline.

Production-shaped: seeded and shardable (each data shard derives its rows
from (seed, step, global row index) — no coordination needed), checkpointable
(the cursor IS the step), and instrumented: every batch fetch goes through
the sys_data_fetch framework syscall, so eBPF filter programs can skip or
veto batches (the opensnoop/filter analogue for the input path).
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig


def _philox_like(seed: int, step: int, rows: int, cols: int,
                 vocab: int) -> np.ndarray:
    """Cheap counter-based deterministic token generator (splitmix-based)."""
    with np.errstate(over="ignore"):
        idx = (np.arange(rows, dtype=np.uint64)[:, None]
               * np.uint64(1 << 32)
               + np.arange(cols, dtype=np.uint64)[None, :])
        x = (idx + np.uint64(seed) * np.uint64(0x9E3779B97F4A7C15)
             + np.uint64(step) * np.uint64(0xBF58476D1CE4E5B9))
        x ^= x >> np.uint64(30)
        x *= np.uint64(0xBF58476D1CE4E5B9)
        x ^= x >> np.uint64(27)
        x *= np.uint64(0x94D049BB133111EB)
        x ^= x >> np.uint64(31)
        return (x % np.uint64(vocab)).astype(np.int32)


def _lm_sequences(seed: int, step: int, rows: int, cols: int,
                  vocab: int) -> np.ndarray:
    """LEARNABLE sequences: per-row random start, then the deterministic
    successor t[i+1] = (a*t[i] + c) % vocab — a 1-gram function a model
    learns in a few steps (used so train-loop tests can assert loss drops;
    the token distribution stays uniform)."""
    start = _philox_like(seed, step, rows, 1, vocab)[:, 0].astype(np.int64)
    a, c = 5, 7
    out = np.empty((rows, cols), np.int64)
    out[:, 0] = start
    for i in range(1, cols):
        out[:, i] = (a * out[:, i - 1] + c) % vocab
    return out.astype(np.int32)


class SyntheticDataset:
    def __init__(self, cfg: ModelConfig, shape: ShapeConfig,
                 tcfg: TrainConfig, seed: int = 0, runtime=None,
                 fault_retries: int = 3):
        self.cfg, self.shape, self.tcfg = cfg, shape, tcfg
        self.seed = seed
        self.runtime = runtime
        self.fault_retries = fault_retries
        self.step = 0           # checkpointable cursor

    def state(self) -> dict:
        return {"seed": self.seed, "step": self.step}

    def restore(self, st: dict):
        self.seed, self.step = st["seed"], st["step"]

    def _make(self, step: int) -> dict:
        cfg, shape = self.cfg, self.shape
        B, S = shape.global_batch, shape.seq_len
        Ft = cfg.frontend_tokens
        rng = np.random.default_rng(self.seed * 1_000_003 + step)
        batch = {}
        if cfg.family == "encdec":
            batch["enc_embeds"] = rng.standard_normal(
                (B, S, cfg.d_model), dtype=np.float32) * 0.02
            batch["tokens"] = _philox_like(self.seed, step, B, S,
                                           cfg.vocab_size)
            batch["labels"] = _philox_like(self.seed, step + 1, B, S,
                                           cfg.vocab_size)
        elif cfg.frontend != "none":
            batch["embeds"] = rng.standard_normal(
                (B, Ft, cfg.d_model), dtype=np.float32) * 0.02
            batch["tokens"] = _philox_like(self.seed, step, B, S - Ft,
                                           cfg.vocab_size)
            labels = _philox_like(self.seed, step + 1, B, S, cfg.vocab_size)
            labels[:, :Ft] = -1
            batch["labels"] = labels
        else:
            toks = _lm_sequences(self.seed, step, B, S, cfg.vocab_size)
            batch["tokens"] = toks
            labels = np.roll(toks, -1, axis=1)
            labels[:, -1] = -1              # no target for the last position
            batch["labels"] = labels
        if self.tcfg.microbatch:
            m = self.tcfg.microbatch
            assert B % m == 0
            batch = {k: v.reshape((B // m, m) + v.shape[1:])
                     for k, v in batch.items()}
        return batch

    def next(self) -> dict | None:
        """Returns the next batch, or None if an eBPF filter skipped it.

        A NEGATIVE override code (-errno) is a transient read fault: the
        same fetch is retried up to fault_retries times before degrading
        to a skip. A non-negative override is a policy veto: the batch is
        skipped immediately (no retry)."""
        step = self.step
        self.step += 1
        if self.runtime is None:
            return self._make(step)
        for _ in range(self.fault_retries + 1):
            res = self.runtime.syscalls.invoke(
                "sys_data_fetch", [step, self.shape.global_batch],
                impl=lambda: self._make(step))
            if not res.overridden:
                return res.value
            if not res.fault:
                return None          # veto: skip this batch
        return None                  # persistent fault: degrade to skip
