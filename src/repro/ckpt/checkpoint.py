"""Checkpointing: sharded numpy save/restore with async commit and ELASTIC
resharding (restore onto a different mesh — the fault-tolerance path).

Layout:  <dir>/step_<N>/ leaf files `<flat-index>.npy` + `tree.json`
Commit protocol: write into `step_<N>.tmp`, fsync, atomic rename — a crash
mid-save never corrupts the latest checkpoint. `latest()` returns the
newest COMMITTED step. Saves go through the sys_checkpoint_save framework
syscall (eBPF programs can audit or veto them).
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _paths_of(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, _leaf in flat:
        out.append("/".join(str(getattr(p, "key", getattr(p, "idx", "?")))
                            for p in path))
    return out


def save(ckpt_dir: str, step: int, state, *, runtime=None,
         blocking: bool = True,
         fault_retries: int = 3) -> threading.Thread | None:
    """state: pytree of arrays. Returns the writer thread if async.

    Failure drill semantics (DESIGN.md §11): an eBPF filter overriding
    sys_checkpoint_save with a NEGATIVE code (-errno) is a transient write
    fault — the save is retried up to `fault_retries` times, then skipped
    (training continues; the previous committed checkpoint stays latest).
    A non-negative override is a policy veto: skipped immediately."""
    leaves, treedef = _flatten(state)
    host = [np.asarray(x) for x in leaves]
    names = _paths_of(state)

    def impl():
        tmp = os.path.join(ckpt_dir, f"step_{step}.tmp")
        final = os.path.join(ckpt_dir, f"step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp, exist_ok=True)
        for i, arr in enumerate(host):
            np.save(os.path.join(tmp, f"{i}.npy"), arr)
        with open(os.path.join(tmp, "tree.json"), "w") as f:
            json.dump({"n": len(host), "names": names, "step": step}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        return step

    def run():
        if runtime is not None:
            for _ in range(fault_retries + 1):
                res = runtime.syscalls.invoke("sys_checkpoint_save",
                                              [step, len(host)], impl=impl)
                if not res.overridden:
                    return res.value
                if not res.fault:
                    return None      # policy veto: no retry
            return None              # fault persisted: degrade (skip save)
        return impl()

    if blocking:
        run()
        return None
    t = threading.Thread(target=run, daemon=True)
    t.start()
    return t


def latest(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp") and \
                os.path.exists(os.path.join(ckpt_dir, d, "tree.json")):
            steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like, *, mesh=None, shardings=None,
            runtime=None, fault_retries: int = 3):
    """Restore into the structure of `like`. With mesh+shardings, leaves are
    device_put with the TARGET sharding — elastic resharding: a checkpoint
    written on one mesh restores onto any other (bytes are mesh-agnostic
    full arrays; the placement is re-derived)."""
    def impl():
        d = os.path.join(ckpt_dir, f"step_{step}")
        with open(os.path.join(d, "tree.json")) as f:
            meta = json.load(f)
        leaves, treedef = _flatten(like)
        assert meta["n"] == len(leaves), \
            f"checkpoint has {meta['n']} leaves, expected {len(leaves)}"
        out = []
        for i, ref in enumerate(leaves):
            arr = np.load(os.path.join(d, f"{i}.npy"))
            assert arr.shape == tuple(ref.shape), \
                f"leaf {i}: {arr.shape} != {ref.shape}"
            out.append(arr)
        if shardings is not None:
            shard_leaves = jax.tree_util.tree_leaves(shardings)
            out = [jax.device_put(a, s) for a, s in zip(out, shard_leaves)]
        else:
            out = [jax.numpy.asarray(a) for a in out]
        return jax.tree_util.tree_unflatten(treedef, out)

    if runtime is not None:
        # same drill convention as save(): negative override = transient
        # read fault, bounded retry; non-negative = veto (returns None)
        for _ in range(fault_retries + 1):
            res = runtime.syscalls.invoke("sys_checkpoint_restore", [step],
                                          impl=impl)
            if not res.overridden or not res.fault:
                return res.value
        return None
    return impl()
