"""repro: bpftime-on-TPU — userspace-eBPF-style observability/control runtime
for JAX training & serving, plus the surrounding production framework.

x64 note: the probe VM is a faithful 64-bit eBPF subset, so 64-bit integer
types must be real. We enable jax_enable_x64 globally and keep EVERY model
dtype explicit (bf16/f32/i32) — a test asserts no f64 leaks into lowered
step functions.
"""
import jax

jax.config.update("jax_enable_x64", True)

__version__ = "1.0.0"
