"""Jitted serving steps: prefill and decode, with the probe stage fused in
(instrumented serving — per-request latency/step histograms via eBPF maps
without leaving the device)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import events as E, jit as J
from repro.models import registry as MR

F32 = jnp.float32


def make_decode_step(cfg: ModelConfig, runtime=None, probe_mode=None):
    wanted = runtime.wanted_sites() if runtime else set()

    def decode_step(params, tokens, cache, maps, step):
        """tokens [B,1] i32; returns (next_token [B], logits, cache, maps)."""
        col = E.Collector(wanted) if runtime else None
        ctx = col if col is not None else _null()
        with ctx:
            logits, cache = MR.decode_fn(params, tokens, cache, cfg)
            if col is not None:
                E.probe_site("decode.logits", logits)
                rows = col.take_all_rows()
            else:
                rows = jnp.zeros((0, E.EVENT_WIDTH), jnp.int64)
        # mask vocab padding before argmax
        logits = logits.at[..., cfg.vocab_size:].set(-jnp.inf) \
            if cfg.padded_vocab > cfg.vocab_size else logits
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        aux = J.make_aux(time_ns=step.astype(jnp.int64))
        if runtime is not None and rows.shape[0] > 0:
            rows = rows.at[:, 3].set(step.astype(jnp.int64))
            maps, aux = runtime.probe_stage(rows, maps, aux, mode=probe_mode)
        return nxt, logits, cache, maps

    return decode_step


def make_prefill_step(cfg: ModelConfig, runtime=None):
    wanted = runtime.wanted_sites() if runtime else set()

    def prefill_step(params, batch, cache, maps):
        col = E.Collector(wanted) if runtime else None
        ctx = col if col is not None else _null()
        with ctx:
            logits, cache = MR.prefill_fn(params, batch, cache, cfg)
            rows = (col.take_all_rows() if col is not None
                    else jnp.zeros((0, E.EVENT_WIDTH), jnp.int64))
        aux = J.make_aux()
        if runtime is not None and rows.shape[0] > 0:
            maps, aux = runtime.probe_stage(rows, maps, aux)
        return logits, cache, maps

    return prefill_step


class _null:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False
