"""Continuous-batching serve engine (host side).

Fixed-slot batcher: B decode slots; finished/empty slots are refilled from
the queue each iteration (prefill for one request at a time into its slot).
Admission and eviction are framework syscalls, so eBPF filter programs can
reject requests (rate limiting / policy — the paper's syscall filtering in
the serving plane) and tracepoints can account per-request tokens.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import registry as MR
from .steps import make_decode_step


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int = 16
    out: list[int] = field(default_factory=list)
    done: bool = False
    rejected: bool = False


class ServeEngine:
    def __init__(self, params, cfg: ModelConfig, *, slots: int = 4,
                 max_seq: int = 128, runtime=None, eos: int = -1,
                 shm_dir: str | None = None,
                 worker_id: str | None = None):
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.max_seq = max_seq
        self.runtime = runtime
        self.eos = eos
        if runtime is not None and shm_dir:
            # serve workers join the same fleet plane as trainers: per-step
            # map snapshots publish under workers/<wid>/ and live attach
            # requests fan in through this worker's control queue
            runtime.setup_shm(shm_dir, worker_id=worker_id)
        self.cache = MR.make_cache(cfg, slots, max_seq, jnp.float32)
        self.active: list[Request | None] = [None] * slots
        self.maps = runtime.init_device_maps() if runtime else {}
        self._decode = jax.jit(make_decode_step(cfg, runtime))
        self.step_count = 0

    # ------------------------------------------------------------- admission
    def _admit(self, req: Request, fault_retries: int = 3) -> bool:
        """Admission faults vs vetoes (DESIGN.md §11): a NEGATIVE override
        code from the sys_serve_admit filter is a transient fault — retried
        up to fault_retries times before the request degrades to rejected.
        A non-negative override is a policy rejection: final immediately."""
        if self.runtime is None:
            return True
        for _ in range(fault_retries + 1):
            res = self.runtime.syscalls.invoke(
                "sys_serve_admit", [req.rid, len(req.prompt), req.max_new],
                impl=lambda: True)
            if not res.overridden:
                return True
            if not res.fault:
                break                # policy veto: final
        req.rejected = True
        req.done = True
        return False

    def _prefill_slot(self, slot: int, req: Request):
        """Single-request prefill into its slot (row-batched caches)."""
        toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
        # run prefill with batch 1, write into slot via cache surgery
        c1 = MR.make_cache(self.cfg, 1, self.max_seq, jnp.float32)
        logits, c1 = MR.prefill_fn(self.params, {"tokens": toks}, c1,
                                   self.cfg)
        def put(full, one):
            if full.ndim >= 2 and full.shape[1] == self.slots:
                return full.at[:, slot].set(one[:, 0])
            if full.shape[0] == self.slots:
                return full.at[slot].set(one[0])
            return full
        self.cache = jax.tree.map(put, self.cache, c1)
        nxt = int(jnp.argmax(logits[0, -1, :self.cfg.vocab_size]))
        req.out.append(nxt)

    # ------------------------------------------------------------- main loop
    def submit_all(self, requests: list[Request]) -> list[Request]:
        queue = list(requests)
        for r in queue:
            self._admit(r)
        queue = [r for r in queue if not r.rejected]
        pending = list(queue)

        while pending or any(self.active):
            if self.runtime is not None and self.runtime.shm is not None:
                # daemon injection point: live attach requests land on the
                # running decode step without recompiling it
                self.runtime.poll_control()
                self.maps = self.runtime.sync_live_table(self.maps)
            # refill slots
            for s in range(self.slots):
                if self.active[s] is None and pending:
                    req = pending.pop(0)
                    self._prefill_slot(s, req)
                    self.active[s] = req
            # batched decode over occupied slots
            toks = np.zeros((self.slots, 1), np.int32)
            for s, r in enumerate(self.active):
                if r is not None and r.out:
                    toks[s, 0] = r.out[-1]
            nxt, _, self.cache, self.maps = self._decode(
                self.params, jnp.asarray(toks), self.cache, self.maps,
                jnp.int32(self.step_count))
            self.step_count += 1
            if self.runtime is not None:
                self.runtime.publish(self.maps)   # no-op without shm
            nxt = np.asarray(nxt)
            for s, r in enumerate(self.active):
                if r is None:
                    continue
                r.out.append(int(nxt[s]))
                if (len(r.out) >= r.max_new or int(nxt[s]) == self.eos
                        or len(r.prompt) + len(r.out) >= self.max_seq - 1):
                    r.done = True
                    if self.runtime is not None:
                        self.runtime.syscalls.invoke(
                            "sys_serve_evict", [r.rid, len(r.out)],
                            impl=lambda: True)
                    self.active[s] = None
        return requests
