"""Figure 3 analogue: VM/JIT runtime efficiency micro-suite.

The paper compares its LLVM JIT against ubpf/rbpf interpreters, native code
and wasm on: log2_int, prime, memcpy, simple, switch, strcmp_fail,
memory_a_plus_b. We run the SAME workloads, written in our eBPF asm, on:

  interp      reference interpreter (the ubpf analogue)
  jax_jit     bytecode->JAX JIT, compiled (the LLVM-JIT analogue)
  native      hand-written jnp equivalent (the native-code bar)

Reported: ns per program execution.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import asm, jit as J, verifier, vm

BENCHES: dict[str, dict] = {}


def bench(name, text, ctx=(0,) * 8, native=None):
    BENCHES[name] = {"text": text, "ctx": list(ctx), "native": native}


bench("simple", """
    mov r0, 1
    add r0, 2
    lsh r0, 4
    sub r0, 3
    exit
""", native=lambda c: ((1 + 2) << 4) - 3)

bench("memory_a_plus_b", """
    ldxdw r2, [r1+0]
    ldxdw r3, [r1+8]
    add r2, r3
    stxdw [r10-8], r2
    ldxdw r0, [r10-8]
    exit
""", ctx=(17, 25, 0, 0, 0, 0, 0, 0),
    native=lambda c: c[0] + c[1])

bench("log2_int", """
    ldxdw r6, [r1+0]
    mov r0, 0
    loop:
    rsh r6, 1
    jeq r6, 0, out
    add r0, 1
    ja loop
    out:
    exit
""", ctx=(1 << 20, 0, 0, 0, 0, 0, 0, 0),
    native=lambda c: int(jnp.log2(jnp.float32(c[0]))))

bench("prime", """
    ldxdw r6, [r1+0]     ; candidate
    mov r7, 2
    mov r0, 1            ; assume prime
    loop:
    mov r8, r7
    mul r8, r7
    jgt r8, r6, out      ; i*i > n -> prime
    mov r8, r6
    mod r8, r7
    jne r8, 0, next
    mov r0, 0            ; divisible -> not prime
    ja out
    next:
    add r7, 1
    ja loop
    out:
    exit
""", ctx=(10007, 0, 0, 0, 0, 0, 0, 0))

bench("memcpy", """
    lddw r4, 0x1122334455667788   ; init source (verifier demands it)
    stxdw [r10-256], r4
    stxdw [r10-248], r4
    stxdw [r10-240], r4
    stxdw [r10-232], r4
    mov r6, 0            ; iteration count
    loop:
    mov r2, r10
    add r2, -256
    mov r3, r10
    add r3, -128
    ldxdw r4, [r2+0]
    stxdw [r3+0], r4
    ldxdw r4, [r2+8]
    stxdw [r3+8], r4
    ldxdw r4, [r2+16]
    stxdw [r3+16], r4
    ldxdw r4, [r2+24]
    stxdw [r3+24], r4
    add r6, 1
    jlt r6, 8, loop
    ldxdw r0, [r10-128]
    exit
""")

bench("switch", """
    ldxdw r6, [r1+0]
    mov r0, 0
    jeq r6, 1, c1
    jeq r6, 2, c2
    jeq r6, 3, c3
    jeq r6, 4, c4
    jeq r6, 5, c5
    mov r0, 99
    ja out
    c1:
    mov r0, 11
    ja out
    c2:
    mov r0, 22
    ja out
    c3:
    mov r0, 33
    ja out
    c4:
    mov r0, 44
    ja out
    c5:
    mov r0, 55
    out:
    exit
""", ctx=(4, 0, 0, 0, 0, 0, 0, 0), native=lambda c: 44)

bench("strcmp_fail", """
    mov r6, 0x41424344   ; "ABCD"
    stxw [r10-8], r6
    mov r6, 0x41424345   ; "ABCE" -> mismatch at byte 3
    stxw [r10-16], r6
    mov r7, 0
    loop:
    mov r2, r10
    add r2, -8
    mov r3, r10
    add r3, -16
    ldxb r4, [r2+0]
    ldxb r5, [r3+0]
    jne r4, r5, fail
    add r7, 1
    jlt r7, 4, loop
    mov r0, 0
    ja out
    fail:
    mov r0, 1
    out:
    exit
""")


def _run_one(name, spec, iters=300):
    a = asm.assemble(spec["text"])
    vprog = verifier.verify(a.insns, [], ctx_words=8)
    ctx_bytes = vm.pack_ctx(spec["ctx"])

    # interpreter
    t0 = time.perf_counter()
    for _ in range(iters):
        res = vm.run(a.insns, ctx_bytes, [], {})
    t_interp = (time.perf_counter() - t0) / iters

    # JAX JIT (tier follows CFG: dag->T1, loop->T2)
    prog = J.compile_program(vprog)
    ctx = jnp.asarray([vm.s64(x) for x in spec["ctx"]], jnp.int64)
    aux = J.make_aux()
    f = jax.jit(lambda c: prog(c, {}, aux)[0])
    r0 = f(ctx)
    assert int(r0) & ((1 << 64) - 1) == res.r0 & ((1 << 64) - 1), name
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(ctx)
    jax.block_until_ready(out)
    t_jit = (time.perf_counter() - t0) / iters

    # native python/jnp
    t_nat = float("nan")
    if spec["native"] is not None:
        nat = spec["native"]
        t0 = time.perf_counter()
        for _ in range(iters):
            nat(spec["ctx"])
        t_nat = (time.perf_counter() - t0) / iters

    return {"name": name, "tier": vprog.tier,
            "interp_ns": t_interp * 1e9, "jit_ns": t_jit * 1e9,
            "native_ns": t_nat * 1e9,
            "speedup": t_interp / t_jit if t_jit else 0.0}


def run():
    return [_run_one(n, s) for n, s in BENCHES.items()]


def main():
    print("name,tier,interp_ns,jit_ns,native_ns,jit_speedup")
    for r in run():
        print(f"{r['name']},{r['tier']},{r['interp_ns']:.0f},"
              f"{r['jit_ns']:.0f},{r['native_ns']:.0f},"
              f"{r['speedup']:.1f}x")


if __name__ == "__main__":
    main()
