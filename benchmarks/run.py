"""Benchmark harness — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast]
    PYTHONPATH=src python -m benchmarks.run --json BENCH_probe.json

Sections:
  table1   probe latency, kernel-mode vs bpftime-mode (paper Table 1)
  fig3     VM/JIT micro-suite vs interpreter + native (paper Figure 3)
  maps     map-op throughput (ref vs Pallas-interpret)
  probe    probe-stage ns/event per exec mode (scan/vectorized/fused/
           interp — the live program-table lane) + live attach latency
  roofline aggregate of dry-run cells (results/*.json), if present

`--json PATH` runs ONLY the probe-pipeline section and writes the
machine-readable BENCH_probe.json (ns/event per mode + fused-vs-scan
speedup) so subsequent PRs can track the perf trajectory. `--fast` shrinks
the tape (smoke-test mode).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np


def section(title):
    print(f"\n## {title}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--json", metavar="PATH",
                    help="write probe-pipeline results as JSON (runs only "
                         "that section)")
    ap.add_argument("--fleet-counts", default="32",
                    help="comma-separated worker counts for the "
                         "hierarchical fleet-scale sweep (json mode)")
    args = ap.parse_args(argv)

    if args.json:
        # temp-file + atomic rename: fail fast on a bad path without
        # truncating a previous run's results if the benchmark dies
        tmp = args.json + ".tmp"
        with open(tmp, "w"):
            pass
        from benchmarks import probe_pipeline
        counts = tuple(int(c) for c in args.fleet_counts.split(",") if c)
        res = probe_pipeline.run(n_events=512 if args.fast else 4096,
                                 iters=3 if args.fast else 10,
                                 fleet_counts=counts)
        with open(tmp, "w") as f:
            json.dump(res, f, indent=1)
        os.replace(tmp, args.json)
        section(f"probe_pipeline ({res['n_programs']} programs, "
                f"{res['n_events']} events)")
        for mode, r in res["modes"].items():
            print(f"{mode},{r['ns_per_event']:.1f}ns/event")
        if "speedup_fused_vs_scan" in res:
            print(f"# fused vs scan: {res['speedup_fused_vs_scan']:.1f}x")
        if "interp_overhead_vs_scan" in res:
            print(f"# interp lane vs scan: "
                  f"{res['interp_overhead_vs_scan']:.1f}x overhead")
        if "attach_latency_ms" in res:
            print(f"# live attach latency: "
                  f"{res['attach_latency_ms']:.2f}ms (retrace avoided: "
                  f"~{res['modes']['fused']['compile_s']}s)")
        if "promotion" in res:
            pr = res["promotion"]
            print(f"# promotion: interp->fused in "
                  f"{pr['time_to_fused_ms'] / 1e3:.1f}s (background), "
                  f"cached swap {pr['cached_swap_ms']:.1f}ms, "
                  f"bit_identical={pr['bit_identical']}")
        if "fleet" in res:
            print(f"# fleet merge: {res['fleet']['events_per_s']:.0f} "
                  f"events/s across {res['fleet']['workers']} workers")
        if "fleet_recovery" in res:
            fr = res["fleet_recovery"]
            print(f"# fleet recovery: {fr['recovery_ms']:.1f}ms daemon "
                  f"restart (zero_loss={fr['zero_loss']})")
        if "fleet_scale" in res:
            fs = res["fleet_scale"]
            for c in fs["curve"]:
                print(f"# fleet scale: {c['workers']}w tree "
                      f"{c['tree_events_per_s']:.0f} events/s "
                      f"({c['tree_speedup_vs_flat3']:.1f}x vs flat-3, "
                      f"bit_identical={c['bit_identical']})")
        if "widening" in res:
            wf, wb = res["widening"]["fused"], res["widening"]["batched"]
            print(f"# widening: disjoint-update set fused at "
                  f"{wf['ns_per_event']:.0f}ns/event "
                  f"({wf['speedup']:.1f}x vs scan fallback), shared-hash "
                  f"slots batched at {wb['ns_per_event']:.0f}ns/event "
                  f"({wb['speedup']:.1f}x vs demoted row loop)")
        print(f"\nwrote {args.json}\nOK")
        return

    section("table1_probe_latency (ns/event)")
    from benchmarks import table1_probe_latency
    print("name,ns_per_event,notes")
    t1 = table1_probe_latency.run()
    for name, ns, note in t1:
        print(f"{name},{ns:.1f},{note}")
    d = dict((n, v) for n, v, _ in t1)
    user = d.get("uprobe_user") or d.get("embedding_runtime", 0)
    if user:
        print(f"# kernel/user uprobe ratio: "
              f"{d['uprobe_kernel'] / user:.1f}x (paper: ~10x; user side "
              f"uses {'in-step delta' if d.get('uprobe_user') else 'stage cost floor'})")

    section("fig3_vm_perf (ns/exec)")
    from benchmarks import fig3_vm_perf
    print("name,tier,interp_ns,jit_ns,native_ns,jit_speedup")
    for r in fig3_vm_perf.run():
        print(f"{r['name']},{r['tier']},{r['interp_ns']:.0f},"
              f"{r['jit_ns']:.0f},{r['native_ns']:.0f},"
              f"{r['speedup']:.1f}x")

    section("map_ops (us/batch of 256 events)")
    from repro.kernels import ops
    keys = jnp.asarray(np.random.default_rng(0).integers(0, 64, 256),
                       jnp.int64)
    deltas = jnp.ones((256,), jnp.int64)
    valid = jnp.ones((256,), bool)
    kt = jnp.zeros((64,), jnp.int64)
    for impl in ("ref",) + (() if args.fast else ("pallas_interpret",)):
        f = jax.jit(lambda a, b, c, d_, e, f_: ops.hash_fetch_add_batch(
            a, b, c, d_, e, f_, impl=impl))
        out = f(kt, kt, kt, keys, deltas, valid)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(20):
            out = f(kt, kt, kt, keys, deltas, valid)
        jax.block_until_ready(out)
        print(f"hash_fetch_add_batch[{impl}],"
              f"{(time.perf_counter() - t0) / 20 * 1e6:.1f}")

    section("probe_pipeline (ns/event per mode)")
    from benchmarks import probe_pipeline
    res = probe_pipeline.run(n_events=512 if args.fast else 4096,
                             iters=3 if args.fast else 10)
    for mode, r in res["modes"].items():
        print(f"{mode},{r['ns_per_event']:.1f}")
    if "speedup_fused_vs_scan" in res:
        print(f"# fused vs scan: {res['speedup_fused_vs_scan']:.1f}x")

    section("roofline (from dry-run results/)")
    try:
        from benchmarks import roofline_report
        roofline_report.main("results")
    except Exception as e:
        print(f"(no dry-run results yet: {e})")

    print("\nOK")


if __name__ == "__main__":
    main()
