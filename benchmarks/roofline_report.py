"""Aggregate dry-run JSONs into the EXPERIMENTS.md §Roofline table."""
from __future__ import annotations

import glob
import json
import os


def load(results_dir="results"):
    rows = []
    for p in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(p) as f:
            d = json.load(f)
        if "error" in d:
            d["status"] = "FAIL"
        elif "skip" in d:
            d["status"] = "skip"
        else:
            d["status"] = "ok"
        rows.append(d)
    return rows


def fmt_row(d):
    if d["status"] == "skip":
        return (f"| {d.get('arch','?')} | {d.get('shape','?')} | - | skip | "
                f"{d.get('skip','')[:40]} | | | | |")
    if d["status"] == "FAIL":
        return (f"| {d.get('arch','?')} | {d.get('shape','?')} | - | FAIL | "
                f"{d.get('error','')[:40]} | | | | |")
    r = d["roofline"]
    mesh = "x".join(str(x) for x in d["mesh"])
    return ("| {arch} | {shape} | {mesh} | {c:.4f} | {m:.4f} | {n:.4f} | "
            "{dom} | {useful:.2f} | {frac:.3f} |".format(
                arch=d["arch"], shape=d["shape"], mesh=mesh,
                c=r["compute_s"], m=r["memory_s"], n=r["collective_s"],
                dom=r["dominant"], useful=r["useful_flops_ratio"],
                frac=r["roofline_fraction"]))


def main(results_dir="results"):
    rows = load(results_dir)
    sp = [d for d in rows if not d.get("multi_pod") and
          not d.get("probes")]
    print("| arch | shape | mesh | compute_s | memory_s | collective_s |"
          " dominant | model/HLO flops | roofline_frac |")
    print("|---|---|---|---|---|---|---|---|---|")
    for d in sp:
        print(fmt_row(d))
    ok = [d for d in rows if d["status"] == "ok"]
    mp = [d for d in rows if d.get("multi_pod")]
    print(f"\n# cells: {len(rows)} total, {len(ok)} compiled, "
          f"{len([d for d in rows if d['status'] == 'skip'])} skipped, "
          f"{len([d for d in rows if d['status'] == 'FAIL'])} failed; "
          f"multi-pod compiled: {len([d for d in mp if d['status'] == 'ok'])}")


if __name__ == "__main__":
    import sys
    main(sys.argv[1] if len(sys.argv) > 1 else "results")
