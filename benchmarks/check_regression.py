"""CI perf-regression gate over BENCH_probe.json (DESIGN.md §8).

Hard floors:
  * fused-vs-scan speedup >= 5x — the fused pipeline's contract;
  * interpreter-lane (live attach) ns/event within TOLERANCE of the budget
    recorded in benchmarks/BENCH_baseline.json — dispatch-as-data may not
    silently decay;
  * live attach latency within TOLERANCE of its recorded budget — the whole
    point of the lane is that attach is milliseconds, not a retrace;
  * fleet merge throughput (events/s aggregated across 3 workers through
    the interprocess map plane, DESIGN.md §10) no worse than the recorded
    budget divided by TOLERANCE;
  * fleet recovery (DESIGN.md §11): a restarted daemon must restore the
    fold journal and republish within TOLERANCE of the recorded latency,
    and the recovered view must be ZERO-LOSS (bit-identical to the
    pre-crash global view — a hard invariant, no tolerance);
  * interpreter lane <= 5x scan ns/event — the vectorized lockstep
    machine's contract (DESIGN.md §12; a hard ratio, no tolerance, since
    both sides run on the same machine in the same process);
  * promotion (DESIGN.md §12): a live-attached program must auto-promote
    to the fused lane within ONE generation boundary and the swapped lane
    must be BIT-IDENTICAL to the scan oracle (both hard invariants);
    time-to-fused (compile hidden behind interp steps) within TOLERANCE
    of the recorded budget;
  * fleet cold-join (DESIGN.md §13): a worker booting with a warm AOT
    artifact cache must absorb its first probed event within a HARD
    100ms ceiling (no tolerance) and within TOLERANCE of the recorded
    budget, and the deserialized executable must be BIT-IDENTICAL to a
    fresh compile (hard invariant);
  * commutativity widening (DESIGN.md §14): the disjoint-static-update
    program set must stay conflict-free (rule 1 proves it fused — hard),
    fused output must be BIT-IDENTICAL to scan (hard), and the widened
    fused set must beat whole-stage scan by >= WIDEN_FUSED_FLOOR; the
    shared-hash static-key slots must keep their batched vec flags
    (rule 2 — hard), colliding keys must still demote (the widening must
    not over-approximate — hard), and batched must beat the demoted row
    loop by >= WIDEN_BATCHED_FLOOR; both ns/event within TOLERANCE of
    their recorded budgets;
  * fleet scale (DESIGN.md §15): the 32-worker hierarchical (tree) merge
    must sustain >= TREE_SCALE_FLOOR x the same-run flat 3-worker merge's
    steady-state throughput (same-machine same-run anchor — a hard ratio,
    no tolerance), and the tree's global view must be BIT-IDENTICAL to
    the flat merge of the same publish schedule (hard invariant).

    python benchmarks/check_regression.py BENCH_probe.json \
        [--baseline benchmarks/BENCH_baseline.json] [--tolerance 2.0]

Exits 1 with a per-check report on any violation. The tolerance absorbs
CI-runner noise; tighten it as the fleet stabilizes.
"""
from __future__ import annotations

import argparse
import json
import sys

FUSED_FLOOR = 5.0
INTERP_SCAN_CEIL = 5.0
# hard ceiling on warm-cache worker cold-join (DESIGN.md §13): the Nth
# fleet member must reach its first probed event by deserializing the
# shared AOT artifact, never by retracing — an absolute wall, no tolerance
WARM_JOIN_CEIL_MS = 100.0
# floors on what the commutativity widening buys (DESIGN.md §14): the
# previously-demoted sets must actually run on their fast lanes, not
# just be eligible for them
WIDEN_FUSED_FLOOR = 2.0
WIDEN_BATCHED_FLOOR = 1.5
# hard floor on what the hierarchical fleet plane buys (DESIGN.md §15):
# a 32-worker tree must sustain >= 5x the same-run flat 3-worker merge's
# steady-state throughput. Both sides run in the same process on the
# same machine moments apart, so the ratio needs no recorded budget and
# no tolerance.
TREE_SCALE_FLOOR = 5.0


def check(result: dict, baseline: dict, tolerance: float) -> list[str]:
    failures = []

    speedup = result.get("speedup_fused_vs_scan", 0.0)
    if speedup < FUSED_FLOOR:
        failures.append(
            f"fused-vs-scan speedup {speedup:.2f}x is below the "
            f"{FUSED_FLOOR}x floor (DESIGN.md §8)")

    interp = result.get("modes", {}).get("interp", {}).get("ns_per_event")
    budget = baseline.get("modes", {}).get("interp", {}).get("ns_per_event")
    if interp is None:
        failures.append("result json has no interpreter-lane measurement "
                        "(modes.interp.ns_per_event)")
    elif budget and interp > budget * tolerance:
        failures.append(
            f"interpreter lane {interp:.0f}ns/event exceeds budget "
            f"{budget:.0f}ns/event x{tolerance}")

    ratio = result.get("interp_overhead_vs_scan")
    if ratio is None:
        failures.append("result json has no interp_overhead_vs_scan ratio")
    elif ratio > INTERP_SCAN_CEIL:
        failures.append(
            f"interpreter lane is {ratio:.1f}x scan, above the "
            f"{INTERP_SCAN_CEIL}x ceiling (DESIGN.md §12)")

    promo = result.get("promotion")
    promo_budget = baseline.get("promotion", {}).get("time_to_fused_ms")
    if promo is None:
        failures.append("result json has no promotion measurement "
                        "(promotion.time_to_fused_ms)")
    else:
        if not promo.get("bit_identical", False):
            failures.append(
                "promotion BROKE BIT-IDENTITY: interp-phase + fused-phase "
                "counters diverge from the scan oracle (DESIGN.md §12)")
        if not promo.get("promoted_within_one_boundary", False):
            failures.append(
                "promotion did not apply within one generation boundary "
                "after the compile was ready (DESIGN.md §12)")
        if promo_budget and promo.get("time_to_fused_ms", 0.0) > \
                promo_budget * tolerance:
            failures.append(
                f"promotion time-to-fused {promo['time_to_fused_ms']:.0f}ms "
                f"exceeds budget {promo_budget:.0f}ms x{tolerance}")

    attach = result.get("attach_latency_ms")
    attach_budget = baseline.get("attach_latency_ms")
    if attach is None:
        failures.append("result json has no attach_latency_ms")
    elif attach_budget and attach > attach_budget * tolerance:
        failures.append(
            f"live attach latency {attach:.2f}ms exceeds budget "
            f"{attach_budget:.2f}ms x{tolerance}")

    cj = result.get("cold_join")
    cj_budget = baseline.get("cold_join", {}).get("warm_join_ms")
    if cj is None:
        failures.append("result json has no cold-join measurement "
                        "(cold_join.warm_join_ms)")
    else:
        if not cj.get("bit_identical", False):
            failures.append(
                "cold-join BROKE BIT-IDENTITY: the deserialized AOT "
                "executable diverges from the freshly compiled one "
                "(DESIGN.md §13)")
        warm = cj.get("warm_join_ms", float("inf"))
        if warm > WARM_JOIN_CEIL_MS:
            failures.append(
                f"warm-cache cold-join {warm:.1f}ms exceeds the hard "
                f"{WARM_JOIN_CEIL_MS:.0f}ms ceiling (DESIGN.md §13)")
        if cj_budget and warm > cj_budget * tolerance:
            failures.append(
                f"warm-cache cold-join {warm:.1f}ms exceeds budget "
                f"{cj_budget:.1f}ms x{tolerance}")

    fleet = result.get("fleet", {}).get("events_per_s")
    fleet_budget = baseline.get("fleet", {}).get("events_per_s")
    if fleet is None:
        failures.append("result json has no fleet merge measurement "
                        "(fleet.events_per_s)")
    elif fleet_budget and fleet < fleet_budget / tolerance:
        failures.append(
            f"fleet merge throughput {fleet:.0f} events/s is below budget "
            f"{fleet_budget:.0f}/{tolerance}")

    rec = result.get("fleet_recovery")
    rec_budget = baseline.get("fleet_recovery", {}).get("recovery_ms")
    if rec is None:
        failures.append("result json has no fleet recovery measurement "
                        "(fleet_recovery.recovery_ms)")
    else:
        if not rec.get("zero_loss", False):
            failures.append(
                "fleet recovery LOST DELTAS: recovered global view is not "
                "bit-identical to the pre-crash view (DESIGN.md §11)")
        if rec_budget and rec.get("recovery_ms", 0.0) > \
                rec_budget * tolerance:
            failures.append(
                f"fleet recovery {rec['recovery_ms']:.1f}ms exceeds budget "
                f"{rec_budget:.1f}ms x{tolerance}")

    fs = result.get("fleet_scale")
    if fs is None:
        failures.append("result json has no fleet-scale measurement "
                        "(fleet_scale.tree32_speedup_vs_flat3)")
    else:
        if not fs.get("bit_identical", False):
            failures.append(
                "fleet tree BROKE BIT-IDENTITY: the hierarchical merge's "
                "global view diverges from the flat single-level merge "
                "over the same publish schedule (DESIGN.md §15)")
        ts = fs.get("tree32_speedup_vs_flat3", 0.0)
        if ts < TREE_SCALE_FLOOR:
            failures.append(
                f"tree-{fs.get('gate_workers', 32)} fleet merge is only "
                f"{ts:.2f}x the same-run flat-3 baseline, below the "
                f"{TREE_SCALE_FLOOR}x floor (DESIGN.md §15)")

    wid = result.get("widening")
    wid_base = baseline.get("widening", {})
    if wid is None:
        failures.append("result json has no widening measurement "
                        "(widening.fused / widening.batched)")
    else:
        wf, wb = wid.get("fused", {}), wid.get("batched", {})
        if not wf.get("conflict_free", False):
            failures.append(
                "widening rule 1 REGRESSED: the disjoint-static-update "
                "set is no longer proven conflict-free — the stage fell "
                "back to scan (DESIGN.md §14)")
        if not wf.get("bit_identical", False):
            failures.append(
                "widening BROKE BIT-IDENTITY: fused output for the "
                "widened set diverges from scan (DESIGN.md §14)")
        if wf.get("speedup", 0.0) < WIDEN_FUSED_FLOOR:
            failures.append(
                f"widened fused set {wf.get('speedup', 0):.2f}x vs scan "
                f"is below the {WIDEN_FUSED_FLOOR}x floor")
        wf_budget = wid_base.get("fused", {}).get("ns_per_event")
        if wf_budget and wf.get("ns_per_event", 0.0) > \
                wf_budget * tolerance:
            failures.append(
                f"widened fused set {wf['ns_per_event']:.0f}ns/event "
                f"exceeds budget {wf_budget:.0f}ns/event x{tolerance}")
        if not wb.get("all_slots_batched", False):
            failures.append(
                "widening rule 2 REGRESSED: static-key shared-hash slots "
                "lost their batched vec flag (DESIGN.md §14)")
        if not wb.get("demotion_still_works", False):
            failures.append(
                "widening rule 2 OVER-APPROXIMATES: colliding home-slot "
                "keys no longer demote (DESIGN.md §14)")
        if wb.get("speedup", 0.0) < WIDEN_BATCHED_FLOOR:
            failures.append(
                f"widened batched slots {wb.get('speedup', 0):.2f}x vs "
                f"the demoted row loop is below the "
                f"{WIDEN_BATCHED_FLOOR}x floor")
        wb_budget = wid_base.get("batched", {}).get("ns_per_event")
        if wb_budget and wb.get("ns_per_event", 0.0) > \
                wb_budget * tolerance:
            failures.append(
                f"widened batched slots {wb['ns_per_event']:.0f}ns/event "
                f"exceeds budget {wb_budget:.0f}ns/event x{tolerance}")

    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("result", help="BENCH_probe.json from this run")
    ap.add_argument("--baseline", default="benchmarks/BENCH_baseline.json")
    ap.add_argument("--tolerance", type=float, default=2.0,
                    help="allowed multiple of the recorded budgets")
    args = ap.parse_args(argv)

    with open(args.result) as f:
        result = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)

    failures = check(result, baseline, args.tolerance)
    print(f"fused vs scan: {result.get('speedup_fused_vs_scan', 0):.2f}x "
          f"(floor {FUSED_FLOOR}x)")
    if "interp" in result.get("modes", {}):
        print(f"interp lane:   "
              f"{result['modes']['interp']['ns_per_event']:.0f}ns/event "
              f"(budget {baseline['modes']['interp']['ns_per_event']:.0f} "
              f"x{args.tolerance})")
    if "interp_overhead_vs_scan" in result:
        print(f"interp/scan:   "
              f"{result['interp_overhead_vs_scan']:.2f}x "
              f"(ceiling {INTERP_SCAN_CEIL}x)")
    if "promotion" in result:
        pr = result["promotion"]
        print(f"promotion:     {pr.get('time_to_fused_ms', 0):.0f}ms "
              f"to fused, one_boundary="
              f"{pr.get('promoted_within_one_boundary')}, "
              f"bit_identical={pr.get('bit_identical')} (budget "
              f"{baseline.get('promotion', {}).get('time_to_fused_ms', 0):.0f}"
              f"ms x{args.tolerance})")
    if "attach_latency_ms" in result:
        print(f"attach:        {result['attach_latency_ms']:.2f}ms "
              f"(budget {baseline.get('attach_latency_ms', 0):.2f} "
              f"x{args.tolerance})")
    if "cold_join" in result:
        cj = result["cold_join"]
        print(f"cold join:     {cj.get('warm_join_ms', 0):.1f}ms warm "
              f"(hard ceiling {WARM_JOIN_CEIL_MS:.0f}ms, budget "
              f"{baseline.get('cold_join', {}).get('warm_join_ms', 0):.1f}"
              f"ms x{args.tolerance}, "
              f"bit_identical={cj.get('bit_identical')})")
    if "fleet" in result:
        print(f"fleet merge:   "
              f"{result['fleet']['events_per_s']:.0f} events/s "
              f"(budget {baseline.get('fleet', {}).get('events_per_s', 0):.0f}"
              f" /{args.tolerance})")
    if "fleet_recovery" in result:
        fr = result["fleet_recovery"]
        print(f"fleet recovery: {fr.get('recovery_ms', 0):.1f}ms, "
              f"zero_loss={fr.get('zero_loss')} (budget "
              f"{baseline.get('fleet_recovery', {}).get('recovery_ms', 0):.1f}"
              f"ms x{args.tolerance})")
    if "fleet_scale" in result:
        fs = result["fleet_scale"]
        for c in fs.get("curve", []):
            print(f"fleet scale:   tree-{c['workers']} "
                  f"({c['tree_nodes']} nodes, fan-in "
                  f"{fs.get('fan_in')}): "
                  f"{c['tree_events_per_s']:.0f} events/s = "
                  f"{c['tree_speedup_vs_flat3']:.2f}x flat-3 "
                  f"(floor {TREE_SCALE_FLOOR}x at "
                  f"{fs.get('gate_workers')} workers, "
                  f"bit_identical={c['bit_identical']})")
    if "widening" in result:
        wf = result["widening"].get("fused", {})
        wb = result["widening"].get("batched", {})
        print(f"widening:      fused {wf.get('speedup', 0):.1f}x vs scan "
              f"(floor {WIDEN_FUSED_FLOOR}x, "
              f"conflict_free={wf.get('conflict_free')}, "
              f"bit_identical={wf.get('bit_identical')}); "
              f"batched {wb.get('speedup', 0):.1f}x vs demoted "
              f"(floor {WIDEN_BATCHED_FLOOR}x, "
              f"all_batched={wb.get('all_slots_batched')}, "
              f"demotes={wb.get('demotion_still_works')})")
    if failures:
        for msg in failures:
            print(f"REGRESSION: {msg}", file=sys.stderr)
        return 1
    print("perf gate OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
