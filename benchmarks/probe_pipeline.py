"""Probe-pipeline benchmark: ns/event of the probe-execution stage for a
multi-program tape, per exec mode.

Perf claims tracked across PRs (BENCH_probe.json, gated by
benchmarks/check_regression.py):
  * the fused single-pass pipeline scales with call sites instead of
    programs x events, so it must beat the seed per-attachment scan mode by
    >= 5x on a 3-program / 4096-event tape (DESIGN.md §8);
  * the live program-table interpreter lane ("interp" mode — the same 3
    programs hot-attached instead of compiled in) pays a bounded ns/event
    premium for dispatch-as-data, and its attach latency (encode + verify +
    table sync onto the running compiled step) is milliseconds — vs the
    seconds-scale retrace it replaces;
  * auto-promotion closes the residual interp premium: a live-attached
    program is retraced into the fused lane off the critical path and
    swapped at a generation boundary, bit-identical to the scan oracle
    (time_to_fused is the compile hidden behind ongoing interp steps).

    PYTHONPATH=src python -m benchmarks.run --json BENCH_probe.json
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import events as E, jit as J, maps as M
from repro.core.runtime import BpftimeRuntime

COUNT_BY_LAYER = """
    ldxdw r6, [r1+ctx:layer]
    stxdw [r10-8], r6
    lddw r1, map:bp_layer_counts
    mov r2, r10
    add r2, -8
    mov r3, 1
    call map_fetch_add
    mov r0, 0
    exit
"""

COUNT_KEY_HASH = """
    ldxdw r6, [r1+ctx:layer]
    stxdw [r10-8], r6
    lddw r1, map:bp_key_hash
    mov r2, r10
    add r2, -8
    mov r3, 1
    call map_fetch_add
    mov r0, 0
    exit
"""

HIST_RMS = """
    ldxdw r2, [r1+ctx:rms]
    lddw r1, map:bp_rms_hist
    call hist_add
    mov r0, 0
    exit
"""

MAPS = [
    M.MapSpec("bp_layer_counts", M.MapKind.ARRAY, max_entries=128),
    M.MapSpec("bp_key_hash", M.MapKind.HASH, max_entries=256),
    M.MapSpec("bp_rms_hist", M.MapKind.LOG2HIST),
]


PROGS = [("bp_count", COUNT_BY_LAYER, MAPS[0], "uprobe:bp_block"),
         ("bp_hash", COUNT_KEY_HASH, MAPS[1], "uprobe:bp_block"),
         ("bp_hist", HIST_RMS, MAPS[2], "uretprobe:bp_block")]


def build_runtime() -> BpftimeRuntime:
    """3 programs (ARRAY fetch_add, HASH fetch_add, LOG2HIST) across two
    sites/kinds — the representative per-layer instrumentation load."""
    rt = BpftimeRuntime()
    for name, text, spec, target in PROGS:
        pid = rt.load_asm(name, text, [spec], "uprobe")
        rt.attach(pid, target)
    return rt


def build_live_runtime() -> tuple[BpftimeRuntime, list[int]]:
    """The SAME 3 programs hot-attached through the program table instead
    of compiled into the step — the interpreter-lane workload."""
    rt = BpftimeRuntime()
    for spec in MAPS:
        rt.create_map(spec)
    rt.enable_live_attach(max_programs=4, max_insns=64,
                          arm=("uprobe:bp_block", "uretprobe:bp_block"))
    lids = []
    for name, text, spec, target in PROGS:
        pid = rt.load_asm(name, text, [spec], "uprobe")
        lids.append(rt.attach(pid, target, mode="table", promote=False))
    return rt, lids


def make_tape(n_events: int):
    rng = np.random.default_rng(0)
    rows = np.zeros((n_events, E.EVENT_WIDTH), np.int64)
    rows[:, 0] = E.SITES.get_or_create("bp_block")
    rows[:, 1] = np.where(np.arange(n_events) % 3 == 2, E.KIND_EXIT,
                          E.KIND_ENTRY)
    rows[:, 2] = rng.integers(0, 64, n_events)          # layer
    rows[:, 6] = rng.integers(1, 1 << 30, n_events)     # rms (fx)
    return jnp.asarray(rows)


def _timeit(fn, *args, iters=10, warmup=2, repeats=5):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / iters)
    return best


def _measure_stage(rt, rows, iters, mode=None):
    n_events = rows.shape[0]

    @jax.jit
    def stage(rows, maps):
        maps, _ = rt.probe_stage(rows, maps, J.make_aux(), mode=mode)
        return maps

    maps0 = rt.init_device_maps()
    t0 = time.perf_counter()
    warm = jax.block_until_ready(stage(rows, maps0))
    compile_s = time.perf_counter() - t0
    # steady state: probe maps persist across train steps, so the
    # recurring per-step cost runs on a warmed table (first step pays
    # the cold hash inserts once — reported separately).
    t_cold = _timeit(stage, rows, maps0, iters=iters)
    t = _timeit(stage, rows, warm, iters=iters)
    return stage, {
        "ns_per_event": t / n_events * 1e9,
        "cold_ns_per_event": t_cold / n_events * 1e9,
        "wall_s": t,
        "compile_s": round(compile_s, 3),
    }


def measure_attach_latency(repeats: int = 5) -> float:
    """Wall time to make a program live on an ALREADY-COMPILED step:
    verify-for-table + encode + generation bump + table sync. This is the
    number that replaces the retrace (compile_s above) the epoch lane pays
    per attach."""
    rt, lids = build_live_runtime()
    rows = make_tape(64)

    @jax.jit
    def stage(rows, maps):
        maps, _ = rt.probe_stage(rows, maps, J.make_aux())
        return maps

    maps = jax.block_until_ready(stage(rows, rt.init_device_maps()))
    pid = next(iter(rt.progs))          # re-attach the first program
    rt.detach(lids[0])
    maps = rt.sync_live_table(maps)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        lid = rt.attach(pid, "uprobe:bp_block", mode="table", promote=False)
        maps = rt.sync_live_table(maps)
        jax.block_until_ready(maps["__live_table__"])
        best = min(best, time.perf_counter() - t0)
        rt.detach(lid)
        maps = rt.sync_live_table(maps)
    assert stage._cache_size() == 1, "attach latency bench retraced"
    return best


def measure_promotion(n_events: int = 512, repeats: int = 3,
                      timeout_s: float = 120.0) -> dict:
    """Time-to-fused after a live attach (DESIGN.md §12): the link lands on
    the table lane in ~ms, a background thread retraces the fused lane
    while the (still-compiled) step keeps absorbing events through the
    interpreter, and the swap applies at a generation boundary.  Reports
    the cold path (includes the background compile), the cached path
    (same attach signature re-promoted: pure dictionary hit), and a
    deterministic bit-identity check of interp-phase + fused-phase vs the
    scan oracle over the same tape."""
    rt = BpftimeRuntime()
    for spec in MAPS:
        rt.create_map(spec)
    rt.enable_live_attach(max_programs=4, max_insns=64,
                          arm=("uprobe:bp_block", "uretprobe:bp_block"))
    rows = make_tape(n_events)

    def builder():
        return jax.jit(lambda r, m: rt.probe_stage(r, m, J.make_aux()))

    step = builder()
    maps, _ = jax.tree.map(jax.block_until_ready,
                           step(rows, rt.init_device_maps()))
    sds = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(jnp.shape(a), jnp.result_type(a)),
        (rows, maps))
    rt.enable_promotion(builder, sds, background=True)
    pid = rt.load_asm("bp_count", COUNT_BY_LAYER, [MAPS[0]], "uprobe")

    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        lk = rt.attach(pid, "uprobe:bp_block", mode="table", promote=True)
        maps = rt.sync_live_table(maps)
        while lk.lane != "fused":      # the loop keeps training on interp
            if lk.promotion_state == "failed":
                raise RuntimeError(lk.promotion_error)
            if time.perf_counter() - t0 > timeout_s:
                raise RuntimeError("promotion never applied")
            maps, _ = step(rows, maps)
            maps = rt.sync_live_table(maps)
        fused = rt.take_promoted_step()
        times.append(time.perf_counter() - t0)
        maps, _ = fused(rows, maps)
        rt.detach(lk)
        maps = rt.sync_live_table(maps)
    assert step._cache_size() == 1, "promotion retraced the live step"

    # deterministic bit-identity across the swap boundary (hard gate)
    rt2 = BpftimeRuntime()
    for spec in MAPS:
        rt2.create_map(spec)
    rt2.enable_live_attach(max_programs=4, max_insns=64,
                           arm=("uprobe:bp_block", "uretprobe:bp_block"))
    step2 = jax.jit(lambda r, m: rt2.probe_stage(r, m, J.make_aux()))
    maps2 = rt2.init_device_maps()
    pid2 = rt2.load_asm("bp_count", COUNT_BY_LAYER, [MAPS[0]], "uprobe")
    lk2 = rt2.attach(pid2, "uprobe:bp_block", mode="table")
    maps2 = rt2.sync_live_table(maps2)
    maps2, _ = step2(rows, maps2)                 # interp phase
    sds2 = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(jnp.shape(a), jnp.result_type(a)),
        (rows, maps2))
    rt2.enable_promotion(
        lambda: jax.jit(lambda r, m: rt2.probe_stage(r, m, J.make_aux())),
        sds2, background=False)
    maps2 = rt2.sync_live_table(maps2)            # one generation boundary
    fused2 = rt2.take_promoted_step()
    maps2, _ = fused2(rows, maps2)                # fused phase

    rt3 = BpftimeRuntime()
    rt3.create_map(MAPS[0])
    pid3 = rt3.load_asm("bp_count", COUNT_BY_LAYER, [MAPS[0]], "uprobe")
    rt3.attach(pid3, "uprobe:bp_block", mode="fused")
    stage3 = jax.jit(
        lambda r, m: rt3.probe_stage(r, m, J.make_aux(), mode="scan"))
    maps3 = rt3.init_device_maps()
    for _ in range(2):
        maps3, _ = stage3(rows, maps3)
    bit_identical = bool(np.array_equal(
        np.asarray(maps2["bp_layer_counts"]["values"]),
        np.asarray(maps3["bp_layer_counts"]["values"])))

    return {"time_to_fused_ms": times[0] * 1e3,
            "cached_swap_ms": min(times[1:]) * 1e3 if len(times) > 1
            else None,
            "promoted_within_one_boundary": lk2.lane == "fused",
            "bit_identical": bit_identical}


def measure_cold_join(n_events: int = 512, repeats: int = 3) -> dict:
    """Worker cold-join latency through the fleet AOT artifact cache
    (DESIGN.md §13): worker 1 boots the representative 3-program world,
    compiles its probe-stage step and stores the serialized executable
    under the layout fingerprint; workers 2..N derive the SAME key from
    the same trace inputs and reach their first probed event by
    deserializing instead of retracing.  Reports both boots, asserts the
    warm path actually hit the cache, and checks the deserialized
    executable produces bit-identical map state."""
    import os
    import shutil
    import tempfile

    root = tempfile.mkdtemp(prefix="bpftime_coldjoin_")
    rows = make_tape(n_events)
    try:
        def join(expect_hit: bool):
            """One worker boot: runtime + cache join + AOT step + first
            event batch absorbed (the cold-join critical path)."""
            rt = build_runtime()
            rt.enable_artifact_cache(os.path.join(root, "cache"))
            t0 = time.perf_counter()

            def build():
                return jax.jit(
                    lambda r, m: rt.probe_stage(r, m, J.make_aux()))

            compiled, hit = rt.aot_step(
                build, (rows, rt.init_device_maps()),
                extra_key=("coldjoin", n_events))
            maps, _ = jax.tree.map(jax.block_until_ready,
                                   compiled(rows, rt.init_device_maps()))
            dt = time.perf_counter() - t0
            assert hit == expect_hit, \
                f"cold-join cache hit={hit}, expected {expect_hit}"
            return dt, maps

        cold_s, maps_cold = join(expect_hit=False)   # worker 1 populates
        warm_s, maps_warm = join(expect_hit=True)    # worker 2 reuses
        for _ in range(repeats - 1):
            warm_s = min(warm_s, join(expect_hit=True)[0])
        bit_identical = bool(np.array_equal(
            np.asarray(maps_cold["bp_layer_counts"]["values"]),
            np.asarray(maps_warm["bp_layer_counts"]["values"])))
        return {"cold_join_ms": cold_s * 1e3,
                "warm_join_ms": warm_s * 1e3,
                "speedup": cold_s / max(warm_s, 1e-9),
                "bit_identical": bit_identical}
    finally:
        shutil.rmtree(root, ignore_errors=True)


def measure_fleet_merge(n_workers: int = 3, rounds: int = 8,
                        events_per_round: int = 2048) -> dict:
    """Merge throughput of the interprocess map plane (DESIGN.md §10):
    N workers publish seqlocked snapshots of a representative map set
    (ARRAY + HASH + LOG2HIST), the daemon's Aggregator polls and folds the
    deltas into the global view. events/s counts every map update that
    flowed through the merge; only the aggregation cycles are timed
    (worker-side state updates are precomputed)."""
    import shutil
    import tempfile

    from repro.core import daemon as D, shm as SH

    specs = [M.MapSpec("fl_arr", M.MapKind.ARRAY, max_entries=128),
             M.MapSpec("fl_hash", M.MapKind.HASH, max_entries=256),
             M.MapSpec("fl_hist", M.MapKind.LOG2HIST)]
    per_kind = events_per_round // 3
    root = tempfile.mkdtemp(prefix="bpftime_fleetbench_")
    try:
        regions = {w: SH.ShmRegion.create(root, specs, worker_id=f"w{w}")
                   for w in range(n_workers)}
        states = {w: M.init_states(specs, np) for w in range(n_workers)}
        rng = np.random.default_rng(0)
        agg = D.Aggregator(root)
        agg.poll_once()          # discovery + zero-delta warmup cycle
        total = 0.0
        for _ in range(rounds):
            for w in range(n_workers):
                st = states[w]
                np.add.at(st["fl_arr"]["values"],
                          rng.integers(0, 128, per_kind), 1)
                M.n_hash_fetch_add_batch(
                    st["fl_hash"],
                    rng.integers(0, 64, per_kind).astype(np.int64),
                    np.ones(per_kind, np.int64))
                np.add.at(st["fl_hist"]["bins"],
                          rng.integers(0, 64, per_kind), 1)
                regions[w].publish_device(st)
            t0 = time.perf_counter()
            agg.poll_once()
            total += time.perf_counter() - t0
        n_events = n_workers * rounds * 3 * per_kind
        return {"workers": n_workers, "rounds": rounds,
                "events_per_round_per_worker": 3 * per_kind,
                "merge_wall_s": round(total, 4),
                "events_per_s": n_events / max(total, 1e-9)}
    finally:
        shutil.rmtree(root, ignore_errors=True)


def measure_fleet_scale(worker_counts=(32,), fan_in: int = 6,
                        rounds: int = 7,
                        events_per_round: int = 384) -> dict:
    """Scaling sweep for the hierarchical fleet plane (DESIGN.md §15):
    for each worker count, the same publish schedule is merged twice —
    once by the flat single-level aggregator, once by a fan_in-ary tree
    of node aggregators (each node folds its whole group in one batched
    device reduction, the root folds delta batches) — and the tree's
    final global view is checked BIT-IDENTICAL to the flat one.

    Throughput model: every node is a separate process in production and
    the root drains delta streams asynchronously, so successive rounds
    pipeline through the levels — steady-state events/s is bounded by the
    SLOWEST stage (slowest node of a level, or the root), while the sum
    of stages is the per-round latency (reported per curve entry). The
    gate anchor is the same-run flat 3-worker merge, so the recorded
    speedup compares machines to themselves, not to a recorded wall
    clock."""
    import shutil
    import tempfile

    from repro.core import daemon as D, shm as SH
    from repro.core.treeagg import TreeAggregator

    specs = [M.MapSpec("fs_arr", M.MapKind.ARRAY, max_entries=128),
             M.MapSpec("fs_hash", M.MapKind.HASH, max_entries=256),
             M.MapSpec("fs_hist", M.MapKind.LOG2HIST)]
    per_kind = events_per_round // 3

    def one_run(n_workers: int, tree: bool):
        root = tempfile.mkdtemp(prefix="bpftime_fleetscale_")
        try:
            wids = [f"w{w:03d}" for w in range(n_workers)]
            regions = {w: SH.ShmRegion.create(root, specs, worker_id=wid)
                       for w, wid in enumerate(wids)}
            states = {w: M.init_states(specs, np)
                      for w in range(n_workers)}
            # one seed per run: flat and tree merge IDENTICAL worker
            # content, so the final global views must match bit-for-bit
            rng = np.random.default_rng(11)
            if tree:
                agg = TreeAggregator(root, fan_in=fan_in, depth=1,
                                     worker_ids=wids)
            else:
                agg = D.Aggregator(root)
            def apply_round():
                for w in range(n_workers):
                    st = states[w]
                    np.add.at(st["fs_arr"]["values"],
                              rng.integers(0, 128, per_kind), 1)
                    M.n_hash_fetch_add_batch(
                        st["fs_hash"],
                        rng.integers(0, 64, per_kind).astype(np.int64),
                        np.ones(per_kind, np.int64))
                    np.add.at(st["fs_hist"]["bins"],
                              rng.integers(0, 64, per_kind), 1)
                    regions[w].publish_device(st)

            # warmup must include a DATA round: the coalesce pow2 bucket
            # and the stacked group fold only compile once real deltas
            # flow, and that first compile (~300ms) must not land inside
            # the timed section. Both runs consume the same rng stream,
            # so the warmup content is identical too.
            agg.poll_once()
            apply_round()
            if tree:
                for na in agg.node_aggs:
                    na.poll_once()
                agg.root_agg.poll_once()
            else:
                agg.poll_once()
            # per-STAGE wall samples across rounds. Every node is its own
            # process in production (`node run`) and the root consumes
            # delta streams asynchronously, so successive rounds PIPELINE
            # through the levels: steady-state throughput is set by the
            # slowest stage (a node, or the root), and one round's
            # latency is the sum of stages along a root-ward path. Each
            # stage's cost is the MEDIAN of its samples — a scheduler
            # burp in one stage of one round must not masquerade as a
            # structurally slow pipeline.
            stage_dts: dict[str, list] = {}
            for _ in range(rounds):
                apply_round()
                if tree:
                    for na in agg.node_aggs:
                        t0 = time.perf_counter()
                        na.poll_once()
                        stage_dts.setdefault(na.node_id, []).append(
                            time.perf_counter() - t0)
                    t0 = time.perf_counter()
                    agg.root_agg.poll_once()
                    stage_dts.setdefault("root", []).append(
                        time.perf_counter() - t0)
                else:
                    t0 = time.perf_counter()
                    agg.poll_once()
                    stage_dts.setdefault("root", []).append(
                        time.perf_counter() - t0)
            g = SH.GlobalView.attach(root)
            final = (np.array(g.snapshot("fs_arr")["values"]),
                     np.array(g.snapshot("fs_hist")["bins"]),
                     M.n_hash_items(g.snapshot("fs_hash")))
            med = {s: float(np.median(d)) for s, d in stage_dts.items()}
            # latency: slowest node of each level + the root, end to end
            by_level: dict[str, float] = {}
            for s, m in med.items():
                if s != "root":
                    lvl = s.split("_")[0]
                    by_level[lvl] = max(by_level.get(lvl, 0.0), m)
            latency = sum(by_level.values()) + med["root"]
            events_round = n_workers * 3 * per_kind
            return (events_round / max(max(med.values()), 1e-9),
                    latency * 1e3, final)
        finally:
            shutil.rmtree(root, ignore_errors=True)

    # the anchor is a ~3ms cycle: one run is at the mercy of machine
    # state, so the gate denominator is the median of three full runs
    flat3_runs = [one_run(3, tree=False) for _ in range(3)]
    flat3_eps = float(np.median([r[0] for r in flat3_runs]))
    flat3_lat = float(np.median([r[1] for r in flat3_runs]))
    curve = []
    all_identical = True
    for n in worker_counts:
        flat_eps, flat_lat, flat_final = one_run(n, tree=False)
        tree_eps, tree_lat, tree_final = one_run(n, tree=True)
        identical = (np.array_equal(flat_final[0], tree_final[0])
                     and np.array_equal(flat_final[1], tree_final[1])
                     and flat_final[2] == tree_final[2])
        all_identical = all_identical and identical
        curve.append({
            "workers": int(n),
            "tree_nodes": -(-int(n) // fan_in),
            "flat_events_per_s": flat_eps,
            "flat_round_latency_ms": flat_lat,
            "tree_events_per_s": tree_eps,
            "tree_round_latency_ms": tree_lat,
            "tree_speedup_vs_flat3": tree_eps / max(flat3_eps, 1e-9),
            "bit_identical": bool(identical),
        })
    gate = min(curve, key=lambda c: c["workers"])
    return {"fan_in": fan_in, "rounds": rounds,
            "events_per_round_per_worker": 3 * per_kind,
            "flat3_events_per_s": flat3_eps,
            "curve": curve,
            "bit_identical": bool(all_identical),
            "gate_workers": gate["workers"],
            "tree32_speedup_vs_flat3": gate["tree_speedup_vs_flat3"]}


def measure_fleet_recovery(n_workers: int = 3, rounds: int = 6,
                           events_per_round: int = 1024,
                           repeats: int = 5) -> dict:
    """Daemon crash-recovery latency (DESIGN.md §11): after `rounds` of
    folded publishes, the aggregator is DISCARDED and a fresh one restores
    the fold journal under global/ and republishes. Times the full restart
    path (journal restore + one poll cycle + republish) and checks zero
    loss: the recovered global view is identical to the pre-crash one —
    no delta double-folded, none dropped."""
    import shutil
    import tempfile

    from repro.core import daemon as D, shm as SH

    specs = [M.MapSpec("fl_arr", M.MapKind.ARRAY, max_entries=128),
             M.MapSpec("fl_hash", M.MapKind.HASH, max_entries=256),
             M.MapSpec("fl_hist", M.MapKind.LOG2HIST)]
    per_kind = events_per_round // 3
    root = tempfile.mkdtemp(prefix="bpftime_recoverybench_")
    try:
        regions = {w: SH.ShmRegion.create(root, specs, worker_id=f"w{w}")
                   for w in range(n_workers)}
        states = {w: M.init_states(specs, np) for w in range(n_workers)}
        rng = np.random.default_rng(0)
        agg = D.Aggregator(root)
        for _ in range(rounds):
            for w in range(n_workers):
                st = states[w]
                np.add.at(st["fl_arr"]["values"],
                          rng.integers(0, 128, per_kind), 1)
                M.n_hash_fetch_add_batch(
                    st["fl_hash"],
                    rng.integers(0, 64, per_kind).astype(np.int64),
                    np.ones(per_kind, np.int64))
                np.add.at(st["fl_hist"]["bins"],
                          rng.integers(0, 64, per_kind), 1)
                regions[w].publish_device(st)
            agg.poll_once()
        g = SH.GlobalView.attach(root)
        before = {s.name: {k: np.array(v)
                           for k, v in g.snapshot(s.name).items()}
                  for s in specs}
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            agg = D.Aggregator(root)    # journal restore
            agg.poll_once()             # first cycle republishes
            best = min(best, time.perf_counter() - t0)
        after = {s.name: SH.GlobalView.attach(root).snapshot(s.name)
                 for s in specs}
        # published global maps are bit-stable (hash tables are published
        # in canonical layout), so recovery must reproduce them exactly
        zero_loss = all(
            np.array_equal(before[s.name][f], after[s.name][f])
            for s in specs for f in before[s.name])
        return {"workers": n_workers, "rounds": rounds,
                "recovery_ms": best * 1e3,
                "zero_loss": zero_loss}
    finally:
        shutil.rmtree(root, ignore_errors=True)


WIDEN_UPD = """
    ldxdw r6, [r1+ctx:rms]
    stdw [r10-8], {key}
    stxdw [r10-16], r6
    lddw r1, map:bp_lastseen
    mov r2, r10
    add r2, -8
    mov r3, r10
    add r3, -16
    mov r4, 0
    call map_update_elem
    mov r0, 0
    exit
"""

WIDEN_HASH_ADD = """
    ldxdw r6, [r1+ctx:layer]
    stdw [r10-8], {key}
    lddw r1, map:bp_widen_hash
    mov r2, r10
    add r2, -8
    mov r3, r6
    call map_fetch_add
    mov r0, 0
    exit
"""


def measure_widening(n_events: int = 4096, iters: int = 20) -> dict:
    """What the commutativity-widening rules buy (DESIGN.md §14).

    Fused: the representative 3-program world plus TWO map_update_elem
    programs writing provably-disjoint static cells of a shared ARRAY —
    non-commutative sharing that pre-widening demoted the entire stage to
    per-attachment scan, and that footprint disjointness (rule 1) now
    proves order-free.  Reports fused vs scan ns/event for the 5-program
    set, whether the conflict check really cleared it, and fused/scan
    bit-identity (the certificate).

    Batched: two static-key hash fetch_add programs live-attached with
    home-slot-distinct keys — same-map hash sharing that pre-widening
    forced into the sequential row loop, and that rule 2 keeps on the
    lockstep SIMT lane.  Reports batched vs (force-demoted, via colliding
    keys) ns/event and that both slots really kept their vec flag."""
    from repro.core.runtime import WIDEN_STATS, _has_ordering_conflict

    lastseen = M.MapSpec("bp_lastseen", M.MapKind.ARRAY, max_entries=16)
    rows = make_tape(n_events)

    rt = build_runtime()
    rt.create_map(lastseen)
    for i, key in enumerate((2, 5)):
        pid = rt.load_asm(f"bp_upd{i}", WIDEN_UPD.format(key=key),
                          [lastseen], "uprobe")
        rt.attach(pid, "uprobe:bp_block")
    vps = [rec.vprog for rec in rt.progs.values()]
    before = WIDEN_STATS["fused_disjoint_pairs"]
    conflict_free = not _has_ordering_conflict(vps)
    widened = WIDEN_STATS["fused_disjoint_pairs"] > before

    stage_f, fused = _measure_stage(rt, rows, iters, mode="fused")
    stage_s, scan = _measure_stage(rt, rows, iters, mode="scan")
    mf = jax.block_until_ready(stage_f(rows, rt.init_device_maps()))
    ms = jax.block_until_ready(stage_s(rows, rt.init_device_maps()))
    bit_identical = all(
        np.array_equal(np.asarray(mf[name][k]), np.asarray(ms[name][k]))
        for name in ("bp_layer_counts", "bp_lastseen")
        for k in mf[name])

    def live_hash_world(keys):
        hsh = M.MapSpec("bp_widen_hash", M.MapKind.HASH, max_entries=64)
        lrt = BpftimeRuntime()
        lrt.create_map(hsh)
        lrt.enable_live_attach(
            max_programs=4, max_insns=64,
            arm=("uprobe:bp_block", "uretprobe:bp_block"))
        slots = []
        for i, (key, target) in enumerate(zip(
                keys, ("uprobe:bp_block", "uretprobe:bp_block"))):
            pid = lrt.load_asm(f"bp_wh{i}", WIDEN_HASH_ADD.format(key=key),
                               [hsh], "uprobe")
            slots.append(lrt.attach(pid, target, mode="table",
                                    promote=False).slot)
        return lrt, slots

    def distinct_home_keys(n=64):
        homes, out = set(), []
        for k in range(256):
            h = M._np_hash_idx(k, n)
            if h not in homes:
                homes.add(h)
                out.append(k)
                if len(out) == 2:
                    return out
        raise AssertionError

    def colliding_home_keys(n=64):
        homes = {}
        for k in range(256):
            h = M._np_hash_idx(k, n)
            if h in homes:
                return homes[h], k
            homes[h] = k
        raise AssertionError

    wrt, wslots = live_hash_world(distinct_home_keys())
    all_batched = all(wrt.live.host["vec"][s] == 1 for s in wslots)
    _, batched = _measure_stage(wrt, rows, iters)
    drt, dslots = live_hash_world(colliding_home_keys())
    all_demoted = all(drt.live.host["vec"][s] == 0 for s in dslots)
    _, demoted = _measure_stage(drt, rows, iters)

    return {
        "fused": {
            "n_programs": len(vps),
            "conflict_free": bool(conflict_free and widened),
            "bit_identical": bool(bit_identical),
            "ns_per_event": fused["ns_per_event"],
            "scan_ns_per_event": scan["ns_per_event"],
            "speedup": scan["ns_per_event"]
            / max(fused["ns_per_event"], 1e-12),
        },
        "batched": {
            "all_slots_batched": bool(all_batched),
            "demotion_still_works": bool(all_demoted),
            "ns_per_event": batched["ns_per_event"],
            "demoted_ns_per_event": demoted["ns_per_event"],
            "speedup": demoted["ns_per_event"]
            / max(batched["ns_per_event"], 1e-12),
        },
    }


def run(n_events: int = 4096, iters: int = 20,
        modes=("scan", "vectorized", "fused", "interp"),
        fleet_counts=(32,)) -> dict:
    rt = build_runtime()
    rows = make_tape(n_events)
    out = {"n_events": n_events, "n_programs": len(rt.progs),
           "modes": {}}
    for mode in modes:
        if mode == "interp":
            # same programs, hot-attached: probe stage runs ONLY the
            # program-table interpreter lane
            live_rt, _ = build_live_runtime()
            _, out["modes"]["interp"] = _measure_stage(live_rt, rows, iters)
            continue
        _, out["modes"][mode] = _measure_stage(rt, rows, iters, mode=mode)
    if "scan" in out["modes"] and "fused" in out["modes"]:
        out["speedup_fused_vs_scan"] = (
            out["modes"]["scan"]["ns_per_event"]
            / max(out["modes"]["fused"]["ns_per_event"], 1e-12))
    if "scan" in out["modes"] and "interp" in out["modes"]:
        out["interp_overhead_vs_scan"] = (
            out["modes"]["interp"]["ns_per_event"]
            / max(out["modes"]["scan"]["ns_per_event"], 1e-12))
    if "interp" in modes:
        out["attach_latency_ms"] = measure_attach_latency() * 1e3
        # unified-attach promotion: interp -> compiling -> fused swap
        out["promotion"] = measure_promotion()
    # fleet AOT cache: Nth-worker boot deserializes instead of retracing
    out["cold_join"] = measure_cold_join()
    # interprocess map plane: merge throughput across a 3-worker fleet
    out["fleet"] = measure_fleet_merge(
        events_per_round=max(384, n_events // 2))
    # chaos plane: daemon restart latency + zero-loss journal recovery
    out["fleet_recovery"] = measure_fleet_recovery(
        events_per_round=max(384, n_events // 4))
    # hierarchical fleet plane: tree-vs-flat scaling sweep + identity
    out["fleet_scale"] = measure_fleet_scale(worker_counts=fleet_counts)
    # commutativity widening: previously-demoted program sets stay fast
    out["widening"] = measure_widening(n_events=n_events, iters=iters)
    return out


def main():
    res = run()
    print("mode,ns_per_event,compile_s")
    for mode, r in res["modes"].items():
        print(f"{mode},{r['ns_per_event']:.1f},{r['compile_s']}")
    if "speedup_fused_vs_scan" in res:
        print(f"# fused vs scan: {res['speedup_fused_vs_scan']:.1f}x")
    if "attach_latency_ms" in res:
        print(f"# live attach latency: {res['attach_latency_ms']:.2f}ms "
              f"(vs retrace: {res['modes']['fused']['compile_s']}s)")
    if "promotion" in res:
        pr = res["promotion"]
        cached = (f", cached swap {pr['cached_swap_ms']:.2f}ms"
                  if pr.get("cached_swap_ms") is not None else "")
        print(f"# promotion: interp->fused in {pr['time_to_fused_ms']:.0f}ms"
              f"{cached} (one boundary={pr['promoted_within_one_boundary']},"
              f" bit_identical={pr['bit_identical']})")
    if "cold_join" in res:
        cj = res["cold_join"]
        print(f"# cold join: {cj['warm_join_ms']:.1f}ms warm-cache "
              f"(cold {cj['cold_join_ms']:.0f}ms, {cj['speedup']:.0f}x, "
              f"bit_identical={cj['bit_identical']})")
    if "fleet" in res:
        fl = res["fleet"]
        print(f"# fleet merge: {fl['events_per_s']:.0f} events/s "
              f"across {fl['workers']} workers")
    if "fleet_recovery" in res:
        fr = res["fleet_recovery"]
        print(f"# fleet recovery: {fr['recovery_ms']:.1f}ms daemon restart "
              f"(zero_loss={fr['zero_loss']})")
    if "fleet_scale" in res:
        fs = res["fleet_scale"]
        for c in fs["curve"]:
            print(f"# fleet scale: {c['workers']} workers tree "
                  f"{c['tree_events_per_s']:.0f} events/s "
                  f"(flat {c['flat_events_per_s']:.0f}, "
                  f"{c['tree_speedup_vs_flat3']:.1f}x vs flat-3, "
                  f"bit_identical={c['bit_identical']})")
    if "widening" in res:
        wf, wb = res["widening"]["fused"], res["widening"]["batched"]
        print(f"# widening fused: {wf['n_programs']} progs incl. disjoint "
              f"updates at {wf['ns_per_event']:.1f}ns/event "
              f"({wf['speedup']:.1f}x vs scan, "
              f"conflict_free={wf['conflict_free']}, "
              f"bit_identical={wf['bit_identical']})")
        print(f"# widening batched: shared-hash slots at "
              f"{wb['ns_per_event']:.1f}ns/event "
              f"({wb['speedup']:.1f}x vs demoted row loop, "
              f"all_batched={wb['all_slots_batched']})")


if __name__ == "__main__":
    main()
