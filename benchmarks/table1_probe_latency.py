"""Table 1 analogue: per-event probe overhead, kernel-mode vs bpftime-mode.

Paper's comparison          ->  ours (boundary isomorphism, DESIGN.md §2)
  kernel uprobe (int3 trap)     host-callback probe (io_callback round-trip)
  bpftime userspace uprobe      in-graph compiled probe (fused into step)
  syscall tracepoint            framework-syscall hook (host interpreter)
  embedding runtime             probe_stage alone on a ready event tape

Reported: ns per probe event (CPU wall clock; the RATIO kernel/user is the
reproduced claim — paper reports ~10x on x86, see EXPERIMENTS.md §Table-1).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import events as E, jit as J, maps as M
from repro.core.runtime import BpftimeRuntime
from repro.core import callback_probe

COUNT_PROG = """
    ldxdw r6, [r1+ctx:layer]
    stxdw [r10-8], r6
    lddw r1, map:counts
    mov r2, r10
    add r2, -8
    mov r3, 1
    call map_fetch_add
    mov r0, 0
    exit
"""
ARR = M.MapSpec("counts", M.MapKind.ARRAY, max_entries=256)

N_EVENTS = 64      # probe events per step
N_LAYERS = 64


def _model_step(x):
    """Stand-in compute: a few matmuls per 'layer' with a probe site."""
    for i in range(4):
        x = jnp.tanh(x @ x.T @ x * 1e-3)
    return x


def _timeit(fn, *args, iters=30, warmup=5, repeats=3):
    """min-of-repeats mean (standard microbenchmark practice: the minimum
    is the least-contended estimate)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / iters)
    return best


def _make_runtime(target):
    rt = BpftimeRuntime()
    pid = rt.load_asm("count", COUNT_PROG, [ARR], "uprobe")
    rt.attach(pid, target)
    return rt


def _probed_step_fn(rt, kind, mode="scan"):
    def step(x, maps, step_idx):
        with rt.collector() as col:
            def body(c, i):
                h = E.probe_site("site", c * 1.0, kind=kind)
                return c + h.mean() * 0.0 + 1.0, None
            c, _ = E.probed_scan(body, x.mean(), jnp.arange(N_EVENTS))
            y = _model_step(x) + c * 0.0
            rows = col.take_all_rows()
        aux = J.make_aux(time_ns=step_idx)
        maps, aux = rt.probe_stage(rows, maps, aux, mode=mode)
        return y, maps
    return step


def _callback_step_fn(rt, kind):
    def step(x, step_idx):
        with rt.collector() as col:
            def body(c, i):
                h = E.probe_site("site", c * 1.0, kind=kind)
                return c + h.mean() * 0.0 + 1.0, None
            c, _ = E.probed_scan(body, x.mean(), jnp.arange(N_EVENTS))
            y = _model_step(x) + c * 0.0
            rows = col.take_all_rows()
        tok = callback_probe.host_probe_stage(rt, rows, step_idx)
        return y + tok.astype(y.dtype) * 0.0
    return step


def run() -> list[tuple[str, float, str]]:
    x = jax.random.normal(jax.random.PRNGKey(0), (128, 128), jnp.float32)
    results = []

    # baseline: same step, no probes attached (sites = nops)
    rt_none = BpftimeRuntime()
    base = jax.jit(_probed_step_fn(rt_none, E.KIND_ENTRY))
    maps0 = rt_none.init_device_maps()
    t_base = _timeit(base, x, maps0, jnp.int64(0))

    for label, kind, target in (
            ("uprobe", E.KIND_ENTRY, "uprobe:site"),
            ("uretprobe", E.KIND_EXIT, "uretprobe:site")):
        # bpftime mode (in-graph)
        rt = _make_runtime(target)
        f = jax.jit(_probed_step_fn(rt, kind))
        maps = rt.init_device_maps()
        t_user = _timeit(f, x, maps, jnp.int64(0))
        user_ns = (t_user - t_base) / N_EVENTS * 1e9
        noise_ns = 0.02 * t_base / N_EVENTS * 1e9   # 2% of step ~= noise
        if user_ns < noise_ns:
            results.append((f"{label}_user", max(user_ns, 0.0),
                            f"below step noise floor (~{noise_ns:.0f}ns); "
                            "see embedding_runtime for the stage cost"))
        else:
            results.append((f"{label}_user", user_ns,
                            "in-graph compiled probe (bpftime mode)"))

        # kernel mode (host callback round-trip)
        rt2 = _make_runtime(target)
        g = jax.jit(_callback_step_fn(rt2, kind))
        t_kern = _timeit(g, x, jnp.int64(0), iters=10)
        kern_ns = max(t_kern - t_base, 0) / N_EVENTS * 1e9
        results.append((f"{label}_kernel", kern_ns,
                        "host-callback probe (kernel-uprobe analogue)"))

    # syscall tracepoint: host-side hook around a framework syscall
    rt3 = BpftimeRuntime()
    sys_prog = COUNT_PROG.replace("ctx:layer", "ctx:arg0")
    pid = rt3.load_asm("count", sys_prog, [ARR], "tracepoint")
    rt3.attach(pid, "tracepoint:sys_log:enter")
    iters = 2000
    t0 = time.perf_counter()
    for i in range(iters):
        rt3.syscalls.invoke("sys_log", [i], impl=lambda: None)
    t_hook = (time.perf_counter() - t0) / iters
    t0 = time.perf_counter()
    for i in range(iters):
        pass
    t_plain = (time.perf_counter() - t0) / iters
    results.append(("syscall_tracepoint", (t_hook - t_plain) * 1e9,
                    "host syscall hook (interpreter)"))

    # embedding runtime: probe_stage alone over a ready tape
    rt4 = _make_runtime("uprobe:site")
    rows = np.zeros((N_EVENTS, E.EVENT_WIDTH), np.int64)
    sid = E.SITES.get_or_create("site")
    rows[:, 0] = sid
    rows[:, 2] = np.arange(N_EVENTS)
    rows = jnp.asarray(rows)

    @jax.jit
    def stage_only(rows, maps):
        maps, _ = rt4.probe_stage(rows, maps, J.make_aux())
        return maps

    maps = rt4.init_device_maps()
    t_stage = _timeit(stage_only, rows, maps)
    results.append(("embedding_runtime", t_stage / N_EVENTS * 1e9,
                    "probe_stage alone (per event)"))

    # vectorized mode (beyond-paper TPU adaptation)
    rt5 = _make_runtime("uprobe:site")

    @jax.jit
    def stage_vec(rows, maps):
        maps, _ = rt5.probe_stage(rows, maps, J.make_aux(),
                                  mode="vectorized")
        return maps

    t_vec = _timeit(stage_vec, rows, rt5.init_device_maps())
    results.append(("embedding_runtime_vectorized", t_vec / N_EVENTS * 1e9,
                    "batched probe stage (beyond-paper)"))
    return results


def main():
    print("name,ns_per_event,notes")
    for name, ns, note in run():
        print(f"{name},{ns:.1f},{note}")


if __name__ == "__main__":
    main()
