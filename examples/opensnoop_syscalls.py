"""opensnoop analogue: trace framework syscalls (data fetches, checkpoint
saves) with enter/exit tracepoints + a ring buffer, and FILTER some of them
(syscall-hook override, paper C2).

    PYTHONPATH=src python examples/opensnoop_syscalls.py
"""
import sys
import tempfile

import jax
import numpy as np

from repro.configs import registry
from repro.configs.base import ShapeConfig, TrainConfig
from repro.core import maps as M
from repro.core.runtime import BpftimeRuntime
from repro.ckpt import checkpoint as CK
from repro.data.pipeline import SyntheticDataset
from repro.train.train_step import init_train_state, make_train_step

SNOOP = """
    ldxdw r6, [r1+ctx:sys_id]
    stxdw [r10-32], r6
    ldxdw r6, [r1+ctx:arg0]
    stxdw [r10-24], r6
    ldxdw r6, [r1+ctx:ret]
    stxdw [r10-16], r6
    lddw r1, map:events
    mov r2, r10
    add r2, -32
    mov r3, 24
    mov r4, 0
    call ringbuf_output
    mov r0, 0
    exit
"""

NO_CKPT_BEFORE_STEP5 = """
    ldxdw r6, [r1+ctx:arg0]     ; step number
    jge r6, 5, allow
    mov r1, -13                 ; -EACCES
    call override_return
    allow:
    mov r0, 0
    exit
"""

def main() -> int:
    rt = BpftimeRuntime()
    rb = M.MapSpec("events", M.MapKind.RINGBUF, max_entries=64, rec_width=3)
    pid = rt.load_asm("snoop", SNOOP, [rb], "tracepoint")
    rt.attach(pid, "tracepoint:sys_data_fetch:exit")
    rt.attach(pid, "tracepoint:sys_checkpoint_save:exit")
    flt = rt.load_asm("nockpt", NO_CKPT_BEFORE_STEP5, [], "filter")
    rt.attach(flt, "filter:sys_checkpoint_save")

    cfg = registry.smoke("mamba2-780m")
    tcfg = TrainConfig(warmup=2)
    state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg, rt)
    step = jax.jit(make_train_step(cfg, tcfg, rt))
    data = SyntheticDataset(cfg, ShapeConfig("o", 32, 4, "train"), tcfg,
                            runtime=rt)

    ckpt_dir = tempfile.mkdtemp(prefix="opensnoop_ckpt_")
    for _ in range(8):
        state, m = step(state, data.next())
        CK.save(ckpt_dir, int(state["step"]), state, runtime=rt)

    latest = CK.latest(ckpt_dir)
    print(f"latest committed checkpoint: step {latest} "
          "(steps 1-4 were vetoed by the filter)\n")

    from repro.core.syscalls import SYSCALL_IDS
    names = {v: k for k, v in SYSCALL_IDS.items()}
    recs, _ = M.n_ringbuf_drain(rt.host_maps["events"], 0)
    print(f"{'SYSCALL':24s} {'ARG0':>6s} {'RET':>5s}")
    for sid, arg0, ret in recs[-16:]:
        print(f"{names.get(sid, sid):24s} {arg0:6d} {ret:5d}")

    assert latest == 8, f"filter should only block steps < 5, got {latest}"
    assert recs, "ring buffer should have captured syscall records"
    assert any(names.get(sid) == "sys_checkpoint_save" and ret != 0
               for sid, _a, ret in recs), "no vetoed save was traced"
    print("OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
