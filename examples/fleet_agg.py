"""Fleet aggregation demo: THREE worker processes, ONE global view.

Each worker is an independent process running its own BpftimeRuntime with a
LOG2HIST probe compiled into its step; all three join the same shm region
under workers/<wid>/. The parent runs the daemon's aggregation engine
(`daemon.Aggregator`), which polls every worker's seqlocked snapshots,
merges the per-worker histograms with the commutative delta-sum twins, and
publishes one fleet-wide histogram under <dir>/global/ — the paper's
"interprocess eBPF Maps within shared memory, catering to summary
aggregation" (C3), at N>1 for the first time.

    PYTHONPATH=src python examples/fleet_agg.py

Asserts (exits non-zero on failure):
  * the merged global LOG2HIST is bin-for-bin the SUM of what each worker
    measured locally;
  * every worker (including ones that already exited) is accounted for in
    the aggregation status;
  * the bpftool-style CLI can read the global view;
  * every worker boots its probe step through the fleet AOT artifact
    cache (DESIGN.md §13), and a LATE joiner booting after the fleet has
    populated <root>/cache hits it — deserialize, zero retraces;
  * act 2 (DESIGN.md §15): a TWELVE-worker fleet aggregated through the
    hierarchical tree (worker -> node -> root, fan-in 4, delta streams)
    converges to the exact bin-wise sum AND comes out bit-identical to a
    flat aggregator merging the same publish content.
"""
import json
import multiprocessing as mp
import os
import shutil
import sys
import tempfile
import time

import numpy as np

N_WORKERS = 3
N_STEPS = 4
EVENTS_PER_STEP = 64

HIST_RMS = """
    ldxdw r2, [r1+ctx:rms]
    lddw r1, map:fleet_hist
    call hist_add
    mov r0, 0
    exit
"""


def worker_main(root: str, wid: str) -> None:
    """One trainer-analogue process: probe compiled into its step, shm
    joined as workers/<wid>/, one publish per step."""
    import jax
    import jax.numpy as jnp
    from repro.core import events as E, jit as J, maps as M
    from repro.core.runtime import BpftimeRuntime

    rt = BpftimeRuntime()
    spec = M.MapSpec("fleet_hist", M.MapKind.LOG2HIST)
    pid = rt.load_asm("fleet_hist_rms", HIST_RMS, [spec], "uprobe")
    rt.attach(pid, "uprobe:fleet_block")
    rt.setup_shm(root, worker_id=wid)     # auto-joins <root>/cache

    def build():
        return jax.jit(
            lambda rows, maps: rt.probe_stage(rows, maps, J.make_aux())[0])

    maps = rt.init_device_maps()
    sig = jnp.asarray(np.zeros((EVENTS_PER_STEP, E.EVENT_WIDTH), np.int64))
    t0 = time.perf_counter()
    # boot through the fleet AOT cache: first worker compiles + stores,
    # later joiners deserialize instead of retracing
    stage, cache_hit = rt.aot_step(build, (sig, maps),
                                   extra_key=("fleet_agg", EVENTS_PER_STEP))
    boot_ms = (time.perf_counter() - t0) * 1e3
    rt.publish_status()      # surface hit/miss counters in status.json
    with open(os.path.join(root, f"cachejoin_{wid}.json"), "w") as f:
        json.dump({"wid": wid, "hit": cache_hit, "boot_ms": boot_ms}, f)
    rng = np.random.default_rng(seed=int(wid[1:]))
    sid = E.SITES.get_or_create("fleet_block")
    for step in range(N_STEPS):
        rows = np.zeros((EVENTS_PER_STEP, E.EVENT_WIDTH), np.int64)
        rows[:, 0] = sid
        rows[:, 1] = E.KIND_ENTRY
        rows[:, 3] = step
        rows[:, 6] = rng.integers(1, 1 << 24, EVENTS_PER_STEP)  # rms (fx)
        maps = stage(jnp.asarray(rows), maps)
        rt.publish(maps)
    # leave the locally-measured truth on disk for the parent's assertion
    np.save(os.path.join(root, f"expect_{wid}.npy"),
            np.asarray(maps["fleet_hist"]["bins"]))


TREE_WORKERS = 12
TREE_FAN_IN = 4
TREE_ROUNDS = 4
TREE_EVENTS = 256


def tree_worker_main(root: str, wid: str) -> None:
    """Lightweight shm-only worker for the tree act: publishes LOG2HIST
    deltas straight through the map plane (no jax runtime — the tree
    demo is about the aggregation topology, not program execution)."""
    from repro.core import maps as M, shm as SH

    specs = [M.MapSpec("tree_hist", M.MapKind.LOG2HIST)]
    region = SH.ShmRegion.create(root, specs, worker_id=wid)
    state = M.init_states(specs, np)
    rng = np.random.default_rng(seed=int(wid[1:]))
    for _ in range(TREE_ROUNDS):
        np.add.at(state["tree_hist"]["bins"],
                  rng.integers(0, 64, TREE_EVENTS), 1)
        region.publish_device(state)
        time.sleep(0.01)
    np.save(os.path.join(root, f"expect_{wid}.npy"),
            np.asarray(state["tree_hist"]["bins"]))


def _run_tree_fleet(root: str, tree: bool) -> np.ndarray:
    """Spawn TREE_WORKERS publishers into `root` and aggregate them live —
    hierarchically (fan-in-4 tree of NodeAggregators) or flat — returning
    the final global bins after the dead-worker harvest."""
    from repro.core import daemon, shm as SH
    from repro.core.treeagg import TreeAggregator

    ctx = mp.get_context("spawn")
    wids = [f"w{i:03d}" for i in range(TREE_WORKERS)]
    procs = [ctx.Process(target=tree_worker_main, args=(root, wid))
             for wid in wids]
    for p in procs:
        p.start()
    agg = None
    while any(p.is_alive() for p in procs):
        if agg is None and len(SH.list_workers(root)) == TREE_WORKERS:
            agg = (TreeAggregator(root, fan_in=TREE_FAN_IN, worker_ids=wids)
                   if tree else daemon.Aggregator(root))
        if agg is not None:
            agg.poll_once()
        time.sleep(0.02)
    for p in procs:
        p.join()
    assert all(p.exitcode == 0 for p in procs), \
        f"tree worker crashed: {[p.exitcode for p in procs]}"
    if agg is None:
        agg = (TreeAggregator(root, fan_in=TREE_FAN_IN, worker_ids=wids)
               if tree else daemon.Aggregator(root))
    status = agg.poll_once()          # final harvest (dead-worker rule)
    assert set(status["alive"]) | set(status["dead"]) == set(wids), status
    expect = sum(np.load(os.path.join(root, f"expect_{w}.npy"))
                 for w in wids)
    merged = SH.GlobalView.attach(root).snapshot("tree_hist")["bins"]
    np.testing.assert_array_equal(merged, expect)
    return np.asarray(merged)


def main() -> int:
    root = tempfile.mkdtemp(prefix="bpftime_fleet_")
    try:
        return _run(root)
    finally:
        shutil.rmtree(root, ignore_errors=True)


def _run(root: str) -> int:
    from repro.core import daemon, shm as SH

    ctx = mp.get_context("spawn")     # fresh interpreters (jax-safe)
    wids = [f"w{i}" for i in range(N_WORKERS)]
    procs = [ctx.Process(target=worker_main, args=(root, wid))
             for wid in wids]
    for p in procs:
        p.start()

    # aggregate WHILE the fleet runs (workers publish every step), then do
    # a final harvest once everyone has exited
    agg = None
    while any(p.is_alive() for p in procs):
        if agg is None and SH.list_workers(root):
            agg = daemon.Aggregator(root)
        if agg is not None:
            agg.poll_once()
        time.sleep(0.05)
    for p in procs:
        p.join()
    assert all(p.exitcode == 0 for p in procs), \
        f"worker crashed: {[p.exitcode for p in procs]}"
    if agg is None:
        agg = daemon.Aggregator(root)
    status = agg.poll_once()          # final harvest (dead-worker rule)

    merged = SH.GlobalView.attach(root).snapshot("fleet_hist")["bins"]
    expect = sum(np.load(os.path.join(root, f"expect_{w}.npy"))
                 for w in wids)
    print(f"fleet status: accounted={sorted(status['alive']) + sorted(status['dead'])} "
          f"merged_updates={status['merged_updates']}")
    print(daemon.render_log2_hist(merged, label="rms"))
    print(f"\nglobal total={int(merged.sum())} "
          f"(= {N_WORKERS} workers x {N_STEPS * EVENTS_PER_STEP} events)")

    assert sorted(status["alive"]) + sorted(status["dead"]) and \
        set(status["alive"]) | set(status["dead"]) == set(wids), status
    np.testing.assert_array_equal(merged, expect)
    assert int(merged.sum()) == N_WORKERS * N_STEPS * EVENTS_PER_STEP

    # the bpftool-style CLI reads the same global view
    rc = daemon.main([root, "map", "top", "fleet_hist", "-n", "3"])
    assert rc == 0
    print("OK: global histogram is the exact bin-wise sum of all workers")

    # -- fleet cold-join: a LATE worker boots the same world against the
    # now-populated AOT cache and must hit (deserialize, zero retraces)
    late = ctx.Process(target=worker_main, args=(root, f"w{N_WORKERS}"))
    late.start()
    late.join()
    assert late.exitcode == 0, f"late joiner crashed: {late.exitcode}"
    with open(os.path.join(root, f"cachejoin_w{N_WORKERS}.json")) as f:
        join_info = json.load(f)
    assert join_info["hit"], \
        f"late joiner missed the warm AOT cache: {join_info}"
    rc = daemon.main([root, "prog", "cache", "stat"])
    assert rc == 0
    print(f"OK: late joiner w{N_WORKERS} warm cold-join in "
          f"{join_info['boot_ms']:.1f}ms (AOT cache hit)")

    # -- act 2: the SAME publish content (per-worker seeds) merged two
    # ways — a fan-in-4 tree of NodeAggregators over delta streams, and
    # the flat single-consumer plane — must land on ONE answer
    tree_root = tempfile.mkdtemp(prefix="bpftime_tree_")
    flat_root = tempfile.mkdtemp(prefix="bpftime_flat_")
    try:
        tree_bins = _run_tree_fleet(tree_root, tree=True)
        flat_bins = _run_tree_fleet(flat_root, tree=False)
    finally:
        shutil.rmtree(tree_root, ignore_errors=True)
        shutil.rmtree(flat_root, ignore_errors=True)
    np.testing.assert_array_equal(tree_bins, flat_bins)
    n_nodes = -(-TREE_WORKERS // TREE_FAN_IN)
    print(f"\ntree fleet: {TREE_WORKERS} workers -> {n_nodes} node "
          f"aggregators (fan-in {TREE_FAN_IN}) -> global root: "
          f"total={int(tree_bins.sum())} "
          f"(= {TREE_WORKERS} workers x {TREE_ROUNDS * TREE_EVENTS} events)")
    print("OK: hierarchical tree view is bit-identical to the flat merge")
    return 0


if __name__ == "__main__":
    sys.exit(main())
