"""Instrumented serving: continuous batching with an eBPF admission filter
(reject long prompts) and a per-request token-count map.

    PYTHONPATH=src python examples/serve_demo.py
"""
import jax
import numpy as np

from repro.configs import registry
from repro.core import maps as M
from repro.core.runtime import BpftimeRuntime
from repro.models import registry as MR
from repro.serve.engine import Request, ServeEngine

ADMIT = """
    ldxdw r6, [r1+ctx:arg1]     ; prompt length
    jle r6, 12, ok
    mov r1, 429                 ; too long -> reject
    call override_return
    ok:
    mov r0, 0
    exit
"""

COUNT_TOKENS = """
    ldxdw r6, [r1+ctx:arg0]     ; request id
    stxdw [r10-8], r6
    ldxdw r3, [r1+ctx:arg1]     ; generated tokens (read ctx BEFORE lddw r1)
    lddw r1, map:tokens_out
    mov r2, r10
    add r2, -8
    call map_fetch_add
    mov r0, 0
    exit
"""

rt = BpftimeRuntime()
pid = rt.load_asm("admit", ADMIT, [], "filter")
rt.attach(pid, "filter:sys_serve_admit")
pid2 = rt.load_asm(
    "count", COUNT_TOKENS,
    [M.MapSpec("tokens_out", M.MapKind.ARRAY, max_entries=64)],
    "tracepoint")
rt.attach(pid2, "tracepoint:sys_serve_evict:enter")

cfg = registry.smoke("qwen2-0.5b")
params = MR.init_params(jax.random.PRNGKey(0), cfg)
engine = ServeEngine(params, cfg, slots=4, max_seq=64, runtime=rt)

rng = np.random.default_rng(0)
reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size,
                                           rng.integers(3, 20)).tolist(),
                max_new=8) for i in range(8)]
engine.submit_all(reqs)

print(f"{'REQ':>4s} {'PROMPT':>6s} {'STATUS':10s} OUTPUT")
for r in reqs:
    status = "rejected" if r.rejected else "done"
    print(f"{r.rid:4d} {len(r.prompt):6d} {status:10s} {r.out[:8]}")
counts = rt.host_maps["tokens_out"]["values"]
print(f"\nper-request generated tokens (eBPF map): "
      f"{ {i: int(c) for i, c in enumerate(counts) if c} }")
print(f"decode steps run: {engine.step_count}")
