"""MoE router monitoring — the expert-balance use case: per-expert load in
an eBPF map + drop-rate histogram, watched during training of a (reduced)
llama4-scout MoE.

    PYTHONPATH=src python examples/moe_balance.py
"""
import jax
import numpy as np

from repro.configs import registry
from repro.configs.base import ShapeConfig, TrainConfig
from repro.core import maps as M
from repro.core.runtime import BpftimeRuntime
from repro.data.pipeline import SyntheticDataset
from repro.train.train_step import init_train_state, make_train_step

# moe.load site emits the router's per-expert token counts as stats:
# mean*E = tokens routed; we histogram the MAX load (imbalance indicator)
# and count drops per step.
BALANCE = """
    ldxdw r2, [r1+ctx:max]       ; max per-expert load this step
    lddw r1, map:load_hist
    call hist_add
    mov r0, 0
    exit
"""
DROPS = """
    ldxdw r6, [r1+ctx:mean]      ; drops count (scalar tensor -> mean)
    mov r7, 0
    stxdw [r10-8], r7
    lddw r1, map:total_drops
    mov r2, r10
    add r2, -8
    arsh r6, 16                  ; fixed-point -> integer
    mov r3, r6
    call map_fetch_add
    mov r0, 0
    exit
"""

rt = BpftimeRuntime()
p1 = rt.load_asm("balance", BALANCE,
                 [M.MapSpec("load_hist", M.MapKind.LOG2HIST)])
rt.attach(p1, "probe:moe.load")
p2 = rt.load_asm("drops", DROPS,
                 [M.MapSpec("total_drops", M.MapKind.ARRAY, max_entries=4)])
rt.attach(p2, "probe:moe.drops")

cfg = registry.smoke("llama4-scout-17b-a16e")
tcfg = TrainConfig(warmup=2)
state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg, rt)
step = jax.jit(make_train_step(cfg, tcfg, rt))
data = SyntheticDataset(cfg, ShapeConfig("m", 64, 8, "train"), tcfg,
                        runtime=rt)
for i in range(6):
    state, m = step(state, data.next())

from repro.core.daemon import render_log2_hist
print("max per-expert load histogram (per router invocation):")
print(render_log2_hist(np.asarray(state["maps"]["load_hist"]["bins"]),
                       label="max load"))
drops = int(np.asarray(state["maps"]["total_drops"]["values"])[0])
print(f"\ntotal capacity drops across run: {drops}")
