"""End-to-end driver: train a ~100M-param llama-family model for a few
hundred steps with checkpointing, fault-tolerant resume, and full bpftime
instrumentation (the deliverable-(b) end-to-end scenario).

    PYTHONPATH=src python examples/train_e2e.py --steps 300
    (defaults to 40 steps so CI finishes quickly; --steps 300 for the
     full run, ~15 min on one CPU core)
"""
import argparse
import dataclasses
import tempfile
import time

import jax
import numpy as np

from repro.configs import registry
from repro.configs.base import ShapeConfig, TrainConfig
from repro.core import maps as M
from repro.core.daemon import render_log2_hist
from repro.core.runtime import BpftimeRuntime
from repro.ckpt import checkpoint as CK
from repro.data.pipeline import SyntheticDataset
from repro.train.train_step import init_train_state, make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=40)
ap.add_argument("--resume", action="store_true")
args = ap.parse_args()

# ~100M params: llama3.2 family, 12 layers, d=512 (84M + embeddings)
cfg = dataclasses.replace(
    registry.get("llama3.2-1b"), num_layers=12, d_model=512, num_heads=8,
    num_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32768,
    dtype="float32")
print(f"model: {cfg.param_counts()['total'] / 1e6:.0f}M params")

PROG = """
    mov r9, r1                   ; save ctx across helper calls
    ldxdw r6, [r1+ctx:layer]
    stxdw [r10-8], r6
    lddw r1, map:layer_hits
    mov r2, r10
    add r2, -8
    mov r3, 1
    call map_fetch_add
    ldxdw r2, [r9+ctx:rms]
    lddw r1, map:act_hist
    call hist_add
    mov r0, 0
    exit
"""
rt = BpftimeRuntime()
pid = rt.load_asm("watch", PROG, [
    M.MapSpec("layer_hits", M.MapKind.ARRAY, max_entries=64),
    M.MapSpec("act_hist", M.MapKind.LOG2HIST)])
rt.attach(pid, "uprobe:block")

tcfg = TrainConfig(warmup=20, total_steps=max(args.steps, 100), lr=6e-4,
                   microbatch=2)
shape = ShapeConfig("e2e", seq_len=64, global_batch=4, mode="train")
ckpt_dir = "/tmp/train_e2e_ckpt"

state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg, rt)
if args.resume and CK.latest(ckpt_dir) is not None:
    like = jax.eval_shape(lambda: init_train_state(
        jax.random.PRNGKey(0), cfg, tcfg, rt))
    state = CK.restore(ckpt_dir, CK.latest(ckpt_dir), like, runtime=rt)
    print(f"resumed from step {int(state['step'])}")

data = SyntheticDataset(cfg, shape, tcfg, runtime=rt)
data.step = int(state["step"])          # checkpointable cursor
step = jax.jit(make_train_step(cfg, tcfg, rt, probe_mode="vectorized"))

t0 = time.time()
losses = []
while int(state["step"]) < args.steps:
    batch = data.next()
    if batch is None:
        continue
    state, m = step(state, batch)
    s = int(state["step"])
    losses.append(float(m["loss"]))
    if s % 10 == 0:
        CK.save(ckpt_dir, s, state, runtime=rt, blocking=False)
        print(f"step {s:4d}  loss {losses[-1]:.4f}  "
              f"gnorm {float(m['grad_norm']):.3f}  "
              f"{(time.time() - t0) / max(s, 1):.2f}s/step")

print(f"\nloss: {losses[0]:.3f} -> {losses[-1]:.3f} over {len(losses)} steps")
hits = np.asarray(state["maps"]["layer_hits"]["values"])[:cfg.num_layers]
print(f"probe hits/layer: {hits.tolist()}")
print(render_log2_hist(np.asarray(state["maps"]["act_hist"]["bins"]),
                       label="act rms"))
print(f"latest checkpoint: step {CK.latest(ckpt_dir)} at {ckpt_dir} "
      "(rerun with --resume)")
