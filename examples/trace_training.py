"""The paper's flagship scenario on a trainer: attach to a RUNNING training
loop without restarting it — without RECOMPILING the step (PR 2) — and,
since PR 7, without paying the interpreter forever: the live-injected
program lands on the table lane in ~ms, a background thread retraces the
fused lane off the critical path, and the runtime swaps the compiled step
in at the next generation boundary.  The injected probe's life is the full
promotion state machine: interp -> compiling -> ready -> fused.

    PYTHONPATH=src python examples/trace_training.py
    # in another shell, while it runs:
    PYTHONPATH=src python -m repro.core.daemon /tmp/bpftime_shm --once
"""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.configs.base import ShapeConfig, TrainConfig
from repro.core import loader, maps as M
from repro.core.daemon import render_log2_hist, request_load_attach
from repro.core.runtime import BpftimeRuntime
from repro.core.shm import ShmRegion
from repro.data.pipeline import SyntheticDataset
from repro.train.train_step import init_train_state, make_train_step

SHM = os.environ.get("BPFTIME_SHM", "/tmp/bpftime_shm")

GRAD_WATCH = """
    ldxdw r2, [r1+ctx:rms]
    lddw r1, map:grad_hist
    call hist_add
    mov r0, 0
    exit
"""


def main() -> int:
    rt = BpftimeRuntime()
    rt.create_map(M.MapSpec("grad_hist", M.MapKind.LOG2HIST))
    # live lane: arm the candidate site BEFORE compiling (the patched-but-
    # idle trampoline); any verified program can hot-attach to it later
    rt.enable_live_attach(max_programs=4, max_insns=64,
                          arm=("probe:grad.norm",))
    rt.setup_shm(SHM)
    print(f"shm control plane at {SHM}")

    cfg = registry.smoke("qwen2-0.5b")
    tcfg = TrainConfig(warmup=2)
    state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg, rt)
    data = SyntheticDataset(cfg, ShapeConfig("t", 64, 8, "train"), tcfg,
                            runtime=rt)

    def build_step():
        return jax.jit(make_train_step(cfg, tcfg, rt))

    step = build_step()

    # --- steps 0-4: UNinstrumented (armed site emits, table is empty)
    for _ in range(5):
        state, m = step(state, data.next())
    hist0 = int(np.asarray(state["maps"]["grad_hist"]["bins"]).sum())
    print(f"steps 0-4 uninstrumented: loss={float(m['loss']):.4f}, "
          f"hist events={hist0}")
    assert hist0 == 0, "empty table must execute nothing"
    assert step._cache_size() == 1

    # --- arm background promotion: hand the engine the loop's step builder
    # and call signature, so a live-injected link converges to fused cost
    batch0 = data.next()
    sig = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(jnp.shape(a), jnp.result_type(a)),
        (state, batch0))
    rt.enable_promotion(build_step, sig)

    # --- a 'daemon' injects a grad-norm watcher into the RUNNING loop
    obj = loader.build_object(
        "grad_watch", GRAD_WATCH,
        [M.MapSpec("grad_hist", M.MapKind.LOG2HIST)],
        prog_type="uprobe", attach_to="probe:grad.norm")
    other = ShmRegion.attach(SHM)
    request_load_attach(other, obj.to_json(), mode="table", promote=True)

    applied = rt.poll_control()             # picked up between steps
    assert applied and "error" not in applied[0], applied
    link = rt.links[applied[0]["link_id"]]
    state["maps"] = rt.sync_live_table(state["maps"])
    print(f"live-injected: link {int(link)} on lane {link.lane!r} "
          f"(table gen {int(rt.live.host['gen'][0])}, promotion "
          f"{link.promotion_state!r}) — training did NOT restart")
    assert link.lane == "table"

    # --- steps 5-9: interpreted on the SAME compiled step while the
    # promotion thread retraces the fused lane in the background
    for i in range(5):
        state, m = step(state, batch0 if i == 0 else data.next())
    hist1 = int(np.asarray(state["maps"]["grad_hist"]["bins"]).sum())
    print(f"steps 5-9 on the table lane: hist events={hist1}")
    assert hist1 == 5, f"one grad.norm event per step, got {hist1}"
    assert step._cache_size() == 1, \
        "live attach must not retrace/recompile the step"

    # --- the swap: wait for the background compile (a real loop would just
    # keep stepping), apply at the generation boundary, pick up the step
    rt._promoter.wait()
    state["maps"] = rt.sync_live_table(state["maps"])
    fused_step = rt.take_promoted_step()
    assert fused_step is not None, link.promotion_error
    assert link.lane == "fused" and link.promotion_state == "fused"
    print(f"promoted: link {int(link)} now on lane {link.lane!r} "
          f"(background compiles: {rt._promoter.compiles})")

    # --- steps 10-14: fused steady state; the event stream never skipped
    # or double-counted a step across the swap
    for _ in range(5):
        state, m = fused_step(state, data.next())
        rt.publish(state["maps"])
    hist2 = int(np.asarray(state["maps"]["grad_hist"]["bins"]).sum())
    print(f"steps 10-14 on the fused lane: hist events={hist2}")
    assert hist2 == 10, f"exactly one event per instrumented step, {hist2}"
    assert step._cache_size() == 1, "the live step itself never retraced"
    assert rt._promoter.compiles == 1, "promotion compiled exactly once"

    # --- detach via the unified handle; the PRE-promotion step (no static
    # attachment, empty table) shows the probe is really gone
    link.detach()
    state["maps"] = rt.sync_live_table(state["maps"])
    for _ in range(3):
        state, m = step(state, data.next())
    hist3 = int(np.asarray(state["maps"]["grad_hist"]["bins"]).sum())
    assert hist3 == hist2, "detached program kept running"
    assert step._cache_size() == 1

    print("\ngradient-norm histogram (live in shm for the daemon):")
    print(render_log2_hist(np.asarray(state["maps"]["grad_hist"]["bins"]),
                           label="grad_norm"))
    print("OK: table attach -> background promotion -> fused steady state, "
          "jit cache of the running step stayed 1")
    return 0


if __name__ == "__main__":
    sys.exit(main())
