"""The paper's flagship scenario on a trainer: attach to a RUNNING training
loop without restarting it — and, since PR 2, without even RECOMPILING the
step. The step is jitted once with the live program-table lane enabled; a
daemon-side handle then injects a grad-norm watcher through shared memory
and the already-compiled step starts executing it on its next call (watch
the jit cache size stay at 1).

    PYTHONPATH=src python examples/trace_training.py
    # in another shell, while it runs:
    PYTHONPATH=src python -m repro.core.daemon /tmp/bpftime_shm --once
"""
import os
import sys

import jax
import numpy as np

from repro.configs import registry
from repro.configs.base import ShapeConfig, TrainConfig
from repro.core import loader, maps as M
from repro.core.daemon import render_log2_hist, request_load_attach
from repro.core.runtime import BpftimeRuntime
from repro.core.shm import ShmRegion
from repro.data.pipeline import SyntheticDataset
from repro.train.train_step import init_train_state, make_train_step

SHM = os.environ.get("BPFTIME_SHM", "/tmp/bpftime_shm")

GRAD_WATCH = """
    ldxdw r2, [r1+ctx:rms]
    lddw r1, map:grad_hist
    call hist_add
    mov r0, 0
    exit
"""


def main() -> int:
    rt = BpftimeRuntime()
    rt.create_map(M.MapSpec("grad_hist", M.MapKind.LOG2HIST))
    # live lane: arm the candidate site BEFORE compiling (the patched-but-
    # idle trampoline); any verified program can hot-attach to it later
    rt.enable_live_attach(max_programs=4, max_insns=64,
                          arm=("probe:grad.norm",))
    rt.setup_shm(SHM)
    print(f"shm control plane at {SHM}")

    cfg = registry.smoke("qwen2-0.5b")
    tcfg = TrainConfig(warmup=2)
    state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg, rt)
    data = SyntheticDataset(cfg, ShapeConfig("t", 64, 8, "train"), tcfg,
                            runtime=rt)
    step = jax.jit(make_train_step(cfg, tcfg, rt))

    # --- steps 0-4: UNinstrumented (armed site emits, table is empty)
    for _ in range(5):
        state, m = step(state, data.next())
    hist0 = int(np.asarray(state["maps"]["grad_hist"]["bins"]).sum())
    print(f"steps 0-4 uninstrumented: loss={float(m['loss']):.4f}, "
          f"hist events={hist0}")
    assert hist0 == 0, "empty table must execute nothing"
    assert step._cache_size() == 1

    # --- a 'daemon' injects a grad-norm watcher into the RUNNING loop
    obj = loader.build_object(
        "grad_watch", GRAD_WATCH,
        [M.MapSpec("grad_hist", M.MapKind.LOG2HIST)],
        prog_type="uprobe", attach_to="probe:grad.norm")
    other = ShmRegion.attach(SHM)
    request_load_attach(other, obj.to_json(), live=True)

    applied = rt.poll_control()             # picked up between steps
    assert applied and "error" not in applied[0], applied
    state["maps"] = rt.sync_live_table(state["maps"])
    print(f"live-injected: {applied[0]['op']} as link "
          f"{applied[0]['link_id']} (table gen "
          f"{int(rt.live.host['gen'][0])}) — training did NOT restart")

    # --- steps 5-14: instrumented, SAME compiled step; publish for daemons
    for _ in range(10):
        state, m = step(state, data.next())
        rt.publish(state["maps"])
    hist1 = int(np.asarray(state["maps"]["grad_hist"]["bins"]).sum())
    print(f"steps 5-14 instrumented: loss={float(m['loss']):.4f}, "
          f"hist events={hist1}")
    assert hist1 == 10, f"one grad.norm event per step, got {hist1}"
    assert step._cache_size() == 1, \
        "live attach must not retrace/recompile the step"

    # --- detach, still no recompile; events stop
    rt.detach(applied[0]["link_id"])
    state["maps"] = rt.sync_live_table(state["maps"])
    for _ in range(3):
        state, m = step(state, data.next())
    hist2 = int(np.asarray(state["maps"]["grad_hist"]["bins"]).sum())
    assert hist2 == hist1, "detached program kept running"
    assert step._cache_size() == 1

    print("\ngradient-norm histogram (live in shm for the daemon):")
    print(render_log2_hist(np.asarray(state["maps"]["grad_hist"]["bins"]),
                           label="grad_norm"))
    print("OK: attach+detach on the running step, jit cache size stayed 1")
    return 0


if __name__ == "__main__":
    sys.exit(main())
