"""The paper's flagship scenario on a trainer: attach to a RUNNING training
loop without restarting it (ptrace-injection analogue), stream metrics to a
shared-memory control plane another process can watch live.

    PYTHONPATH=src python examples/trace_training.py
    # in another shell, while it runs:
    PYTHONPATH=src python -m repro.core.daemon /tmp/bpftime_shm --once
"""
import os
import tempfile

import jax
import numpy as np

from repro.configs import registry
from repro.configs.base import ShapeConfig, TrainConfig
from repro.core import loader, maps as M
from repro.core.daemon import render_log2_hist, request_load_attach
from repro.core.runtime import BpftimeRuntime
from repro.core.shm import ShmRegion
from repro.data.pipeline import SyntheticDataset
from repro.train.train_step import init_train_state, make_train_step

SHM = os.environ.get("BPFTIME_SHM", "/tmp/bpftime_shm")

GRAD_WATCH = """
    ldxdw r2, [r1+ctx:rms]
    lddw r1, map:grad_hist
    call hist_add
    mov r0, 0
    exit
"""

rt = BpftimeRuntime()
rt.create_map(M.MapSpec("grad_hist", M.MapKind.LOG2HIST))
rt.setup_shm(SHM)
print(f"shm control plane at {SHM}")

cfg = registry.smoke("qwen2-0.5b")
tcfg = TrainConfig(warmup=2)
state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg, rt)
data = SyntheticDataset(cfg, ShapeConfig("t", 64, 8, "train"), tcfg,
                        runtime=rt)

jit_cache = {}
def step_fn():
    e = rt.attach_epoch
    if e not in jit_cache:
        jit_cache[e] = jax.jit(make_train_step(cfg, tcfg, rt))
    return jit_cache[e]

# --- steps 0-4: UNinstrumented (probe sites are nops)
for i in range(5):
    state, m = step_fn()(state, data.next())
print(f"steps 0-4 uninstrumented: loss={float(m['loss']):.4f}, "
      f"hist events={int(np.asarray(state['maps']['grad_hist']['bins']).sum())}")

# --- a 'daemon' injects a grad-norm watcher into the RUNNING loop
obj = loader.build_object(
    "grad_watch", GRAD_WATCH,
    [M.MapSpec("grad_hist", M.MapKind.LOG2HIST)],
    prog_type="uprobe", attach_to="probe:grad.norm")
other = ShmRegion.attach(SHM)
request_load_attach(other, obj.to_json())

applied = rt.poll_control()             # trainer picks it up between steps
print(f"live-injected: {applied[0]['op']} (epoch {rt.attach_epoch}) — "
      "training did NOT restart")

# --- steps 5-14: instrumented; publish maps for the daemon each step
for i in range(10):
    state, m = step_fn()(state, data.next())
    rt.publish(state["maps"])
print(f"steps 5-14 instrumented: loss={float(m['loss']):.4f}")
print("\ngradient-norm histogram (live in shm for the daemon):")
print(render_log2_hist(np.asarray(state["maps"]["grad_hist"]["bins"]),
                       label="grad_norm"))
