"""Quickstart: write an eBPF program, verify it, attach it to a model's
probe sites, run a few training steps, read the maps.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs import registry
from repro.configs.base import ShapeConfig, TrainConfig
from repro.core import maps as M
from repro.core.daemon import render_log2_hist
from repro.core.runtime import BpftimeRuntime
from repro.data.pipeline import SyntheticDataset
from repro.train.train_step import init_train_state, make_train_step

# 1. an eBPF program, in our assembler (the clang stand-in): count events
#    per layer and histogram activation RMS — bcc-style, zero model changes
PROG = """
    mov r9, r1                    ; save ctx (calls clobber r1-r5)
    ldxdw r6, [r1+ctx:layer]      ; CO-RE-lite ctx field relocation
    stxdw [r10-8], r6
    lddw r1, map:layer_hits       ; symbolic map reloc (libbpf-style)
    mov r2, r10
    add r2, -8
    mov r3, 1
    call map_fetch_add
    ldxdw r2, [r9+ctx:rms]        ; Q47.16 fixed-point activation RMS
    lddw r1, map:rms_hist
    call hist_add
    mov r0, 0
    exit
"""

rt = BpftimeRuntime()
pid = rt.load_asm(                      # load = relocate + VERIFY + jit
    "quickstart", PROG,
    maps=[M.MapSpec("layer_hits", M.MapKind.ARRAY, max_entries=64),
          M.MapSpec("rms_hist", M.MapKind.LOG2HIST)])
rt.attach(pid, "uprobe:block")          # fire on every block entry

# 2. train a small model — the probe runs INSIDE the jitted step
cfg = registry.smoke("llama3.2-1b")
tcfg = TrainConfig(warmup=2)
state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg, rt)
step = jax.jit(make_train_step(cfg, tcfg, rt))
data = SyntheticDataset(cfg, ShapeConfig("q", 64, 8, "train"), tcfg,
                        runtime=rt)
for i in range(5):
    state, metrics = step(state, data.next())
    print(f"step {i}: loss={float(metrics['loss']):.4f}")

# 3. read the maps (still functional state — no host round-trips happened)
hits = np.asarray(state["maps"]["layer_hits"]["values"])
print(f"\nper-layer probe hits: {hits[:cfg.num_layers].tolist()}")
print("\nactivation RMS histogram (bcc-style):")
print(render_log2_hist(np.asarray(state["maps"]["rms_hist"]["bins"]),
                       label="rms"))
