"""Chaos drill: a 3-worker fleet survives a SIGKILLed worker AND a daemon
crash, and the recovered global view still converges to the exact oracle.

What happens (DESIGN.md §11):

  * three worker processes join one shm region and publish deterministic
    map updates over several rounds;
  * ONE worker installs a seed-driven FaultPlan that SIGKILLs it mid-
    publish (at the odd-seqlock window) — exactly what a trainer dying
    inside publish_device leaves behind;
  * the daemon aggregates the fleet, then CRASHES at an injected agg:*
    boundary point (InjectedCrash) and is RESTARTED — the new Aggregator
    resumes from the fold journal under global/;
  * the parent asserts: the victim's death is detected (pid gone, stuck-odd
    seqlock never surfaced), its last CONSISTENT contribution is retained,
    the survivors' full contributions merge, and the recovered global view
    is bit-identical to the replayed oracle;
  * `fleet health` renders the victim's transition to DEAD;
  * AOT artifact-cache drill (DESIGN.md §13): a worker's stored step
    executable is corrupted on the cache:post_store hook; the next
    joiner's CRC check detects it, DELETES the torn entry, and degrades
    to recompile — never crashes, never runs corrupted code — and the
    recompiled store serves the joiner after that.

    PYTHONPATH=src python examples/chaos_drill.py

Exits non-zero on any failed invariant.
"""
import json
import multiprocessing as mp
import os
import shutil
import signal
import sys
import tempfile
import time

import numpy as np

N_WORKERS = 3
ROUNDS = 4
VICTIM = "w1"
VICTIM_ROUNDS = 2          # consistent publishes before the SIGKILL

SPECS_ARGS = [("fleet_arr", "ARRAY", 8), ("fleet_hist", "LOG2HIST", 64)]


def _specs():
    from repro.core import maps as M
    return [M.MapSpec(n, M.MapKind[k], max_entries=e)
            for n, k, e in SPECS_ARGS]


def _apply_round(states, w: int, r: int) -> None:
    """Deterministic per-round update: replayable as the oracle."""
    from repro.core import maps as M
    M.n_array_fetch_add(states["fleet_arr"], w, r)
    M.n_hist_add(states["fleet_hist"], (r << 16) + w)


def worker_main(root: str, wid: str, kill_at: int | None,
                counter_file: str | None, go_file: str | None) -> None:
    from repro.core import faults as F, maps as M, shm as SH

    if kill_at is not None:
        # SIGKILL self at the kill_at-th publish_begin — inside the odd
        # seqlock window, counters flushed to disk first
        F.install(F.FaultPlan(seed=0, kill_at=kill_at,
                              counter_file=counter_file))
    specs = _specs()
    region = SH.ShmRegion.create(root, specs, worker_id=wid)
    states = M.init_states(specs, np)
    w = int(wid[1:])
    for r in range(1, ROUNDS + 1):
        _apply_round(states, w, r)
        if go_file is not None and r == VICTIM_ROUNDS + 1:
            # wait until the daemon has folded our consistent publishes,
            # so the drill's oracle is deterministic
            while not os.path.exists(go_file):
                time.sleep(0.01)
        region.publish_device(states)      # the victim dies inside this
        time.sleep(0.02)


def _oracle():
    """Replay: survivors contribute all ROUNDS, the victim only what it
    published consistently before the SIGKILL."""
    from repro.core import maps as M
    st = M.init_states(_specs(), np)
    for w in range(N_WORKERS):
        last = VICTIM_ROUNDS if f"w{w}" == VICTIM else ROUNDS
        for r in range(1, last + 1):
            _apply_round(st, w, r)
    return st


def _run(root: str) -> int:
    counter_file = os.path.join(root, "victim_counters.json")
    go_file = os.path.join(root, "victim_go")
    ctx = mp.get_context("spawn")
    procs = {}
    for w in range(N_WORKERS):
        wid = f"w{w}"
        victim = wid == VICTIM
        procs[wid] = ctx.Process(
            target=worker_main,
            args=(root, wid, VICTIM_ROUNDS + 1 if victim else None,
                  counter_file if victim else None,
                  go_file if victim else None))
        procs[wid].start()
    try:
        return _drill(root, procs, counter_file, go_file)
    finally:
        for p in procs.values():           # never leak children on failure
            if p.is_alive():
                p.kill()
                p.join()


def _drill(root: str, procs: dict, counter_file: str, go_file: str) -> int:
    from repro.core import daemon as D, faults as F, shm as SH

    # the first worker to register writes the region meta
    deadline = time.monotonic() + 60
    while len(SH.list_workers(root)) < N_WORKERS:
        if time.monotonic() > deadline:
            print("FAIL: workers never registered", file=sys.stderr)
            return 1
        time.sleep(0.02)

    # -- aggregate until the victim's consistent publishes are folded
    cfg = D.AggregatorConfig(snapshot_retries=10, backoff_base=1e-4,
                             backoff_max=2e-3)
    agg = D.Aggregator(root, config=cfg)
    deadline = time.monotonic() + 60
    while True:
        agg.poll_once()
        seq = agg.workers.get(VICTIM, {}).get("seq", 0)
        if seq >= 2 * VICTIM_ROUNDS:       # 2 seq ticks per publish
            break
        if time.monotonic() > deadline:
            print("FAIL: victim publishes never observed", file=sys.stderr)
            return 1
        time.sleep(0.02)
    print(f"folded {VICTIM_ROUNDS} consistent publishes from {VICTIM}")

    # -- daemon crash at an injected aggregation boundary + restart
    with F.plan(F.FaultPlan(seed=0, crash_at=2)):
        try:
            agg.poll_once()
            print("FAIL: injected daemon crash did not fire",
                  file=sys.stderr)
            return 1
        except F.InjectedCrash as e:
            print(f"daemon crashed (injected): {e}")
    agg = D.Aggregator(root, config=cfg)   # journal recovery
    print("daemon restarted from the fold journal")

    # -- release the victim into its fatal publish
    with open(go_file, "w") as f:
        f.write("go")
    procs[VICTIM].join(timeout=60)
    if procs[VICTIM].exitcode != -signal.SIGKILL:
        print(f"FAIL: victim exitcode {procs[VICTIM].exitcode}, expected "
              f"SIGKILL", file=sys.stderr)
        return 1
    with open(counter_file) as f:
        counters = json.load(f)["counters"]
    if counters["kill_worker"] != 1:
        print(f"FAIL: kill_worker counter {counters}", file=sys.stderr)
        return 1
    victim_region = SH.ShmRegion.attach(root, mode="r", worker_id=VICTIM)
    if int(victim_region.seq[0]) % 2 != 1:
        print("FAIL: victim seqlock not odd after mid-publish SIGKILL",
              file=sys.stderr)
        return 1
    print(f"{VICTIM} SIGKILLed mid-publish (seqlock left odd)")

    for wid, p in procs.items():
        if wid != VICTIM:
            p.join(timeout=120)

    # -- final polls: harvest the dead victim, fold the survivors' tails
    status = agg.poll_once()
    status = agg.poll_once()
    # survivors that already exited cleanly are harvested as dead too —
    # the drill's point is that the VICTIM is among them with its stuck-odd
    # final publish forfeited, not silently folded
    if VICTIM not in status["dead"]:
        print(f"FAIL: dead={status['dead']}", file=sys.stderr)
        return 1
    if status["health"][VICTIM]["state"] != D.DEAD:
        print(f"FAIL: health={status['health'][VICTIM]}", file=sys.stderr)
        return 1
    print(f"victim harvested: dead={status['dead']}, "
          f"health[{VICTIM}]={status['health'][VICTIM]['state']}")

    # -- the recovered global view is bit-identical to the oracle
    g = SH.GlobalView.attach(root)
    want = _oracle()
    for name, st in want.items():
        got = g.snapshot(name)
        for fieldname in got:
            if not np.array_equal(got[fieldname],
                                  np.asarray(st[fieldname])):
                print(f"FAIL: {name}.{fieldname}: {got[fieldname]} != "
                      f"{st[fieldname]}", file=sys.stderr)
                return 1
    arr = g.snapshot("fleet_arr")["values"]
    print(f"OK: global view converged to the oracle "
          f"(fleet_arr={arr[:N_WORKERS].tolist()}: survivors "
          f"{sum(range(1, ROUNDS + 1))}, victim "
          f"{sum(range(1, VICTIM_ROUNDS + 1))})")

    # -- fleet health CLI renders the transition
    rc = D.main([root, "fleet", "health"])
    if rc != 0:
        print("FAIL: fleet health CLI", file=sys.stderr)
        return 1

    # -- AOT cache corruption drill
    rc = _cache_drill(root)
    if rc != 0:
        return rc
    print("OK: chaos drill survived worker SIGKILL + daemon crash "
          "+ corrupted AOT artifact")
    return 0


def _cache_drill(root: str) -> int:
    """Corrupted artifact -> CRC detect -> degrade to recompile.

    Worker 1 boots through the cache while the corrupt_artifact fault
    scribbles its stored executable (post-CRC, exactly the torn-write a
    dying disk leaves behind). Worker 2 must see a MISS (never corrupted
    code), recompile, and re-store; worker 3 then hits the clean entry.
    All three produce identical outputs."""
    import jax
    import jax.numpy as jnp

    from repro.core import faults as F
    from repro.core.maps import MapKind, MapSpec
    from repro.core.runtime import BpftimeRuntime

    cache_dir = os.path.join(root, "cache")
    x = jnp.arange(8.0)

    def boot():
        rt = BpftimeRuntime()
        rt.create_map(MapSpec("drill_counts", MapKind.ARRAY, max_entries=8))
        rt.enable_artifact_cache(cache_dir)
        compiled, hit = rt.aot_step(
            lambda: jax.jit(lambda v: v * 2 + 1), (x,),
            extra_key=("cache_drill",))
        return rt.artifact_cache, compiled, hit

    with F.plan(F.FaultPlan(seed=0,
                            rates={"corrupt_artifact": 1.0})) as p:
        _, c1, hit1 = boot()              # populate -> fault scribbles it
        if hit1 or p.counters["corrupt_artifact"] != 1:
            print(f"FAIL: corrupt_artifact never fired (hit={hit1}, "
                  f"counters={p.counters})", file=sys.stderr)
            return 1
    print("stored AOT artifact corrupted (injected, post-CRC)")

    cache2, c2, hit2 = boot()             # CRC detects -> recompile
    if hit2 or cache2.counters["corrupt"] != 1:
        print(f"FAIL: corrupted artifact served or not detected "
              f"(hit={hit2}, counters={cache2.counters})", file=sys.stderr)
        return 1
    print("next joiner: CRC mismatch detected, torn entry deleted, "
          "degraded to recompile")

    cache3, c3, hit3 = boot()             # clean re-store serves again
    if not hit3:
        print(f"FAIL: recompiled artifact not reusable "
              f"(counters={cache3.counters})", file=sys.stderr)
        return 1
    if not (np.array_equal(np.asarray(c1(x)), np.asarray(c2(x)))
            and np.array_equal(np.asarray(c2(x)), np.asarray(c3(x)))):
        print("FAIL: outputs diverged across the corruption drill",
              file=sys.stderr)
        return 1
    print("OK: corruption degraded to recompile; re-stored artifact "
          "hits again, outputs bit-identical")
    return 0


def main() -> int:
    root = tempfile.mkdtemp(prefix="bpftime_chaos_")
    try:
        return _run(root)
    finally:
        shutil.rmtree(root, ignore_errors=True)


if __name__ == "__main__":
    sys.exit(main())
